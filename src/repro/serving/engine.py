"""Slot-based continuous-batching serving engine.

The decode path (``repro.models.transformer.decode_step``) is a fixed-batch
jitted step: caches are ``[B, ...]`` arrays. A production server cannot
re-jit per request mix, so this engine manages B **slots**:

- incoming requests are queued and admitted into free slots;
- each engine ``step()`` decodes ONE token for every active slot (inactive
  slots decode garbage that is ignored — the usual static-batch trick);
- per-slot position counters drive prompt-feeding (prefill runs through the
  same decode step, token by token) and completion detection;
- finished slots return their output and become free for the next queued
  request — i.e. continuous batching at slot granularity.

Cache isolation between consecutive requests in the same slot comes from
positional masking: attention masks ring-buffer slots with
``slot_pos > position`` invalid, and the SSM/conv states are zeroed via the
per-slot reset mask.

This is deliberately mesh-agnostic: under a mesh, ``decode_step`` is the
same jitted function the dry-run lowers for decode_32k/long_500k, with the
cache sharded by ``cache_shardings``.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.telemetry import EventLog, RingTimer


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]            # token ids ([K][S] lists for codebooks)
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled on completion:
    output: Optional[List[int]] = None
    # telemetry timestamps (perf_counter seconds; None until reached):
    t_submit: Optional[float] = None
    t_admit: Optional[float] = None


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                 # next absolute position to feed
    generated: Optional[list] = None

    @property
    def active(self) -> bool:
        return self.req is not None


class ServeEngine:
    def __init__(self, cfg, params, batch_slots: int = 4, max_len: int = 256,
                 sample: str = "greedy", event_log: Optional[EventLog] = None):
        assert not cfg.n_codebooks, "engine currently serves plain-LM archs"
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.sample = sample
        self.cache = tfm.init_cache(cfg, batch_slots, max_len)
        self.slots = [_Slot() for _ in range(batch_slots)]
        self.queue: deque[Request] = deque()
        self.finished: Dict[int, Request] = {}
        self._decode = jax.jit(
            lambda p, c, t, pos: tfm.decode_step(p, cfg, c, t, pos)
        )
        self._zero_cache = jax.jit(self._make_zero_cache)
        # -- telemetry (host-side counters; never touches the jitted step)
        self.event_log = event_log
        self.tokens_total = 0
        self.steps_total = 0
        self.step_timer = RingTimer(256)      # decode step wall time
        self.admit_timer = RingTimer(256)     # submit -> slot admission
        self._token_window: deque = deque(maxlen=256)  # (t, n_new) per step

    # ------------------------------------------------------------- plumbing
    @staticmethod
    def _make_zero_cache(cache, slot_mask):
        """Zero the cache rows of slots in ``slot_mask`` (new admissions)."""
        def one(leaf):
            # leaf: [period, B, ...]; mask over B
            shape = [1, leaf.shape[1]] + [1] * (leaf.ndim - 2)
            m = slot_mask.reshape(shape)
            return jnp.where(m, jnp.zeros_like(leaf), leaf)

        return jax.tree_util.tree_map(one, cache)

    def submit(self, req: Request) -> None:
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _admit(self) -> None:
        newly = jnp.zeros((self.B,), bool)
        any_new = False
        for i, slot in enumerate(self.slots):
            if not slot.active and self.queue:
                req = self.queue.popleft()
                assert len(req.prompt) + req.max_new_tokens <= self.max_len, (
                    "request exceeds engine max_len")
                req.t_admit = time.perf_counter()
                if req.t_submit is not None:
                    self.admit_timer.record(req.t_admit - req.t_submit)
                self.slots[i] = _Slot(req=req, pos=0, generated=[])
                newly = newly.at[i].set(True)
                any_new = True
        if any_new:
            # positional masking isolates attention; recurrent (SSM/conv)
            # state needs an explicit reset.
            self.cache = self._zero_cache(self.cache, newly)

    # ----------------------------------------------------------------- step
    def step(self) -> None:
        """Admit queued requests and decode one token for every active slot."""
        self._admit()
        if not any(s.active for s in self.slots):
            return

        # Slots can be at different positions; the jitted step takes ONE
        # position scalar, so we step the minimum-position cohort. Slots at
        # other positions feed a pad token and ignore the output — position
        # masking keeps their caches untouched beyond slot `pos` bookkeeping
        # only for the stepped cohort.
        active_pos = [s.pos for s in self.slots if s.active]
        pos = min(active_pos)

        toks = []
        stepped = []
        for s in self.slots:
            if s.active and s.pos == pos:
                req = s.req
                if s.pos < len(req.prompt):
                    toks.append(req.prompt[s.pos])
                else:
                    toks.append(s.generated[-1])
                stepped.append(True)
            else:
                toks.append(0)
                stepped.append(False)

        self.step_timer.start()
        logits, new_cache = self._decode(
            self.params, self.cache,
            jnp.asarray(toks, jnp.int32), jnp.asarray(pos, jnp.int32))
        jax.block_until_ready(logits)  # honest step timing (async dispatch)
        self.step_timer.stop()
        self.steps_total += 1

        # non-stepped slots must keep their cache rows (they were written
        # at `pos` with garbage): restore from the old cache.
        keep = jnp.asarray(stepped)

        def merge(new, old):
            shape = [1, new.shape[1]] + [1] * (new.ndim - 2)
            m = keep.reshape(shape)
            return jnp.where(m, new, old)

        self.cache = jax.tree_util.tree_map(merge, new_cache, self.cache)

        nxt = jnp.argmax(logits, axis=-1)  # greedy
        n_new = 0
        for i, s in enumerate(self.slots):
            if not (s.active and stepped[i]):
                continue
            s.pos += 1
            req = s.req
            if s.pos >= len(req.prompt):  # we just consumed prompt/gen token
                tok = int(nxt[i])
                s.generated.append(tok)
                n_new += 1
                done = (len(s.generated) >= req.max_new_tokens
                        or (req.eos_id is not None and tok == req.eos_id))
                if done:
                    req.output = list(s.generated[:req.max_new_tokens])
                    self.finished[req.uid] = req
                    self.slots[i] = _Slot()
        self.tokens_total += n_new
        self._token_window.append((time.perf_counter(), n_new))
        if self.event_log is not None:
            self.event_log.serve(self.stats())

    # ------------------------------------------------------------ telemetry
    def stats(self) -> Dict[str, float]:
        """Current engine metrics snapshot (names from the telemetry
        catalogue: queue depth, active slots, latency, tokens/s)."""
        out: Dict[str, float] = {
            "serve_queue_depth": len(self.queue),
            "serve_active_slots": sum(s.active for s in self.slots),
            "serve_tokens_total": self.tokens_total,
            "serve_steps_total": self.steps_total,
        }
        if len(self.step_timer):
            out["serve_decode_step_s"] = self.step_timer.summary()["mean_s"]
        if len(self.admit_timer):
            out["serve_admit_latency_s"] = self.admit_timer.summary()["mean_s"]
        if len(self._token_window) >= 2:
            t0, _ = self._token_window[0]
            t1, _ = self._token_window[-1]
            if t1 > t0:
                # tokens after the window's first timestamp, over its span
                n = sum(k for _, k in list(self._token_window)[1:])
                out["serve_tokens_per_s"] = n / (t1 - t0)
        return out

    def run_until_drained(self, max_steps: int = 10_000) -> Dict[int, Request]:
        for _ in range(max_steps):
            if not self.queue and not any(s.active for s in self.slots):
                break
            self.step()
        return self.finished
