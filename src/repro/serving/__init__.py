"""Serving runtime: slot-based continuous batching over ``decode_step``."""

from repro.serving.engine import Request, ServeEngine

__all__ = ["Request", "ServeEngine"]
