"""Analysis targets: the compiled programs the static gate inspects.

Each target lowers + compiles one hot-path function on the forced 8-device
host mesh (the same topology as ``tests/test_shard_engine.py`` and the CI
quick job) and hands the rule layers its HLO text, its closed jaxpr, and
per-target expectations (collective budget name, forbidden replicated
shapes, whether the Pallas kernel route must be present).

This module imports jax at call time only — ``repro.analysis.cli`` must be
able to force the host device count before the backend initializes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

from repro.analysis.hlo_lint import HloCheckSpec

MESH_DATA, MESH_MODEL = 4, 2
N_DEVICES = MESH_DATA * MESH_MODEL
SYNC_W = 8            # worker rows in the standalone sync targets
BLOCK_D = 256
TRAIN_ARCH = "qwen2.5-14b"  # fsdp + server-momentum family (smoke-sized)
TRAIN_TARGET = "train_step_qwen2_5_14b_smoke"

#: targets that check ANOTHER target's committed budget (HloCheckSpec.
#: budget_name, exact match) — they never own a budget file and are
#: skipped by ``--update-budgets``'s write phase.
BUDGET_ALIASES = {
    "sync_telemetry_off_rfa_bucketing": "sync_kernels_rfa_bucketing",
}


@dataclasses.dataclass
class AnalysisTarget:
    name: str
    hlo_text: str
    jaxpr: Any                      # ClosedJaxpr
    spec: HloCheckSpec
    expect_pallas: bool = False     # jaxpr layer: require pallas_call eqns
    description: str = ""


def _sync_tree(W: int):
    """Synthetic FSDP-shardable gradient tree (every leaf divisible by both
    mesh axes — the shape class the param-sharded egress exists for)."""
    import jax
    import jax.numpy as jnp

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    return {
        "w": jax.random.normal(ks[0], (W, 16, 48), jnp.float32),
        "b": jax.random.normal(ks[1], (W, 8, 64), jnp.float32),
        "v": jax.random.normal(ks[2], (W, 4, 256), jnp.float32),
    }


def _make_mesh():
    import jax

    from repro.launch.mesh import make_host_mesh

    if jax.device_count() < N_DEVICES:
        raise RuntimeError(
            f"analysis targets need {N_DEVICES} devices, have "
            f"{jax.device_count()} — run via `python -m repro.analysis` "
            f"(which forces the host platform) or set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={N_DEVICES}")
    return make_host_mesh(data=MESH_DATA, model=MESH_MODEL)


def _trace(fn, *args, mesh=None):
    """(closed jaxpr, compiled HLO text) of ``fn`` on the given args."""
    import jax

    with mesh:
        jaxpr = jax.make_jaxpr(fn)(*args)
        hlo = jax.jit(fn).lower(*args).compile().as_text()
    return jaxpr, hlo


def _build_sync_target(name: str, aggregator: str, mixing: str,
                       use_kernels: bool, param_sharded: bool,
                       description: str,
                       telemetry: bool = False,
                       budget_name: Optional[str] = None,
                       exact: bool = False) -> AnalysisTarget:
    import jax

    from repro.core.aragg import RobustAggregator
    from repro.distributed.packing import packer_for
    from repro.distributed.robust_sync import robust_gradient_sync
    from repro.distributed.sharding import param_shardings

    mesh = _make_mesh()
    tree = _sync_tree(SYNC_W)
    ra = RobustAggregator.from_spec(aggregator, mixing=mixing, s=2)
    packer = packer_for(tree, block_d=BLOCK_D)
    out_sh = None
    if param_sharded:
        shapes = jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), tree)
        out_sh = param_shardings(shapes, mesh, fsdp=True)

    def sync(t, k):
        out, _ = robust_gradient_sync(
            t, ra, key=k, mesh=mesh, engine="packed", block_d=BLOCK_D,
            use_kernels=use_kernels, out_shardings=out_sh,
            telemetry=telemetry)
        return out

    jaxpr, hlo = _trace(sync, tree, jax.random.PRNGKey(5), mesh=mesh)
    spec = HloCheckSpec(
        name=name,
        forbid_replicated=(f"f32[{packer.n_pad}]",) if param_sharded else (),
        expect_pallas_custom_call=use_kernels,
        budget_name=budget_name,
        exact=exact,
    )
    return AnalysisTarget(name=name, hlo_text=hlo, jaxpr=jaxpr, spec=spec,
                          expect_pallas=use_kernels, description=description)


def _build_train_target(name: str, arch: str,
                        description: str) -> AnalysisTarget:
    import jax
    import jax.numpy as jnp

    from repro.configs import smoke_config
    from repro.configs.base import ByzConfig, InputShape
    from repro.distributed.steps import (batch_shardings, input_specs,
                                         make_train_step)

    mesh = _make_mesh()
    cfg = smoke_config(arch)
    byz = ByzConfig(aggregator="rfa", mixing="bucketing", s=2,
                    worker_momentum=0.9, delta=0.1)
    shape = InputShape("analysis_train", seq_len=128,
                       global_batch=2 * MESH_DATA, kind="train")
    specs = input_specs(cfg, shape)
    b_sh = batch_shardings(cfg, shape, mesh)
    with mesh:
        step_fn, sh = make_train_step(cfg, byz, mesh)
        args = (sh["params_shape"], sh["opt_shape"], sh["wm_shape"],
                jax.ShapeDtypeStruct((2,), jnp.uint32), specs)
        jaxpr = jax.make_jaxpr(step_fn)(*args)
        rep = sh["replicated"]
        hlo = jax.jit(
            step_fn,
            in_shardings=(sh["params"], sh["opt_state"], sh["worker_m"],
                          rep, b_sh),
            out_shardings=(sh["params"], sh["opt_state"], sh["worker_m"],
                           rep),
        ).lower(*args).compile().as_text()
    spec = HloCheckSpec(name=name)
    return AnalysisTarget(name=name, hlo_text=hlo, jaxpr=jaxpr, spec=spec,
                          expect_pallas=True, description=description)


_BUILDERS = {
    "sync_fsdp_rfa_bucketing": lambda: _build_sync_target(
        "sync_fsdp_rfa_bucketing", "rfa", "bucketing",
        use_kernels=False, param_sharded=True,
        description=("packed sync, GSPMD jnp route, param-sharded egress — "
                     "the no-replicated-[n_pad] invariant + FSDP collective "
                     "budget")),
    "sync_kernels_rfa_bucketing": lambda: _build_sync_target(
        "sync_kernels_rfa_bucketing", "rfa", "bucketing",
        use_kernels=True, param_sharded=False,
        description=("packed sync, shard_map Pallas route (Gram-space RFA) "
                     "— kernel-presence + collective budget")),
    "sync_kernels_cm_bucketing": lambda: _build_sync_target(
        "sync_kernels_cm_bucketing", "cm", "bucketing",
        use_kernels=True, param_sharded=False,
        description=("packed sync, coordinatewise median selection-network "
                     "kernel route — kernel-presence + collective budget")),
    "sync_kernels_cclip_bucketing": lambda: _build_sync_target(
        "sync_kernels_cclip_bucketing", "cclip", "bucketing",
        use_kernels=True, param_sharded=False,
        description=("packed sync, fused multi-device CCLIP route (column-"
                     "sharded cclip_aggregate instead of Gram-space "
                     "weights) — kernel-presence + collective budget")),
    "sync_telemetry_off_rfa_bucketing": lambda: _build_sync_target(
        "sync_telemetry_off_rfa_bucketing", "rfa", "bucketing",
        use_kernels=True, param_sharded=False,
        telemetry=False,
        budget_name=BUDGET_ALIASES["sync_telemetry_off_rfa_bucketing"],
        exact=True,
        description=("packed sync with telemetry explicitly OFF — must "
                     "compile to the byte-identical collective schedule as "
                     "sync_kernels_rfa_bucketing (exact budget match, zero "
                     "tolerance): proof that the observability layer adds "
                     "no collectives when disabled")),
    TRAIN_TARGET: lambda: _build_train_target(
        TRAIN_TARGET, TRAIN_ARCH,
        description=("full train step, smoke-sized FSDP arch with server "
                     "momentum — f64 / host-transfer / callback / budget "
                     "gate on the end-to-end compiled program")),
}

TARGET_NAMES: Tuple[str, ...] = tuple(_BUILDERS)


def build_targets(names: Optional[List[str]] = None) -> List[AnalysisTarget]:
    names = list(names) if names else list(TARGET_NAMES)
    unknown = [n for n in names if n not in _BUILDERS]
    if unknown:
        raise KeyError(f"unknown analysis target(s) {unknown}; "
                       f"have {sorted(_BUILDERS)}")
    return [_BUILDERS[n]() for n in names]
