"""HLO lint: a rule engine over ``compiled.as_text()``.

Grown out of ``repro.launch.hlo_analysis`` (which keeps the import-safe
parsers): this module turns PR 7's hand-verified compiled-program invariants
into executable checks. Rules:

  hlo-collective-count-budget / hlo-collective-bytes-budget
      Per-target collective op counts and payload bytes vs a committed
      budget file (``analysis/budgets/<target>.json``), checked with a
      relative tolerance. A regression in the collective schedule (an extra
      all-gather per leaf, a replicated egress, a 14x ingress blowup) is a
      correctness bug for the paper's bucketing guarantee, not just a perf
      bug — it fails loudly here. A *new* collective kind absent from the
      budget fails too. Large undershoot is a warning (stale budget —
      regenerate with ``--update-budgets``).

  hlo-replicated-egress
      A forbidden replicated buffer shape (e.g. ``f32[n_pad]`` of the
      packed engine) appears in an FSDP-egress program — the exact
      regression the param-sharded unpack of PR 7 eliminated.

  hlo-f64
      Any op computes in f64 (weak-type promotion leaks double precision
      into the train step).

  hlo-host-transfer
      infeed / outfeed / send / recv, or a custom-call into a host Python
      callback, inside the step — host round-trips in the hot path.

  hlo-pallas-missing
      ``use_kernels=True`` but no Pallas kernel custom-call in the compiled
      program. Only meaningful on TPU/GPU backends (CPU interpret-mode
      Pallas lowers to plain HLO); the jaxpr layer
      (``jaxpr-pallas-missing``) covers every backend.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
from typing import Dict, List, Optional, Tuple

from repro.analysis.findings import ERROR, WARNING, Finding
from repro.launch.hlo_analysis import collective_bytes, collective_counts

BUDGET_DIR = os.path.join(os.path.dirname(__file__), "budgets")
DEFAULT_TOLERANCE = 0.25
# collectives smaller than this never trip a byte budget (compiler noise)
_BYTES_SLACK = 4096

_HOST_TRANSFER_OPS = ("infeed", "outfeed", "send", "send-done", "recv",
                      "recv-done")
_CALLBACK_TARGET_RE = re.compile(
    r'custom_call_target="[^"]*(callback|host)[^"]*"', re.IGNORECASE)
_PALLAS_TARGET_RE = re.compile(
    r'custom_call_target="[^"]*(tpu_custom_call|mosaic|triton)[^"]*"',
    re.IGNORECASE)
_OP_LINE_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*")


@dataclasses.dataclass
class HloCheckSpec:
    """What to enforce for one compiled program."""

    name: str                               # target / budget-file stem
    forbid_replicated: Tuple[str, ...] = ()  # e.g. ("f32[49152]",)
    expect_pallas_custom_call: bool = False  # enforce only on tpu/gpu
    check_budget: bool = True
    tolerance: Optional[float] = None        # overrides the budget file's
    #: check against ANOTHER target's committed budget instead of this
    #: target's own file (e.g. the telemetry-off program against the seed
    #: budget). Such targets never write a budget on --update-budgets.
    budget_name: Optional[str] = None
    #: exact comparison: collective counts and bytes must EQUAL the budget
    #: dict (zero tolerance, no slack, no unknown kinds in either
    #: direction). This is the "telemetry off adds nothing" invariant.
    exact: bool = False


# ------------------------------------------------------------------ budgets
def budget_path(name: str, budget_dir: Optional[str] = None) -> str:
    return os.path.join(budget_dir or BUDGET_DIR, f"{name}.json")


def load_budget(name: str, budget_dir: Optional[str] = None) -> Optional[Dict]:
    path = budget_path(name, budget_dir)
    if not os.path.exists(path):
        return None
    with open(path, "r", encoding="utf-8") as fh:
        return json.load(fh)


def make_budget(hlo_text: str, name: str,
                tolerance: float = DEFAULT_TOLERANCE,
                meta: Optional[Dict] = None) -> Dict:
    """Measure a compiled program into a committable budget dict."""
    budget = {
        "target": name,
        "tolerance": tolerance,
        "collective_counts": collective_counts(hlo_text),
        "collective_bytes": collective_bytes(hlo_text),
    }
    if meta:
        budget.update(meta)
    return budget


def write_budget(budget: Dict, budget_dir: Optional[str] = None) -> str:
    path = budget_path(budget["target"], budget_dir)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(budget, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return path


def _check_budget_exact(hlo_text: str, spec: HloCheckSpec,
                        budget: Dict) -> List[Finding]:
    """Byte-identical budget comparison (``HloCheckSpec.exact``): every
    collective kind's count AND bytes must equal the committed budget, in
    both directions. Used to prove the telemetry-off compile IS the seed
    collective schedule — one extra psum or one extra transferred byte
    fails."""
    findings: List[Finding] = []
    ref = budget.get("target", spec.budget_name or spec.name)
    measured = {"collective_counts": collective_counts(hlo_text),
                "collective_bytes": collective_bytes(hlo_text)}
    committed = {"collective_counts": budget.get("collective_counts", {}),
                 "collective_bytes": budget.get("collective_bytes", {})}
    for field, rule in (("collective_counts", "hlo-collective-count-budget"),
                        ("collective_bytes", "hlo-collective-bytes-budget")):
        got, want = measured[field], committed[field]
        if got == want:
            continue
        for kind in sorted(set(got) | set(want)):
            g, w = got.get(kind, 0), want.get(kind, 0)
            if g != w:
                findings.append(Finding(
                    rule=rule, severity=ERROR, target=spec.name,
                    location=f"op kind {kind}",
                    message=(f"{field.split('_')[1]} of {kind}: {g} != "
                             f"{w} committed for {ref!r} (exact match "
                             f"required — this program must compile to the "
                             f"byte-identical collective schedule)")))
    return findings


def _check_budget(hlo_text: str, spec: HloCheckSpec,
                  budget: Optional[Dict]) -> List[Finding]:
    budget_ref = spec.budget_name or spec.name
    if budget is None:
        return [Finding(
            rule="hlo-budget-missing", severity=ERROR, target=spec.name,
            location=budget_path(budget_ref),
            message=("no committed collective budget for this target — "
                     "run `python -m repro.analysis --update-budgets` and "
                     "commit the generated file"))]
    if spec.exact:
        return _check_budget_exact(hlo_text, spec, budget)
    findings: List[Finding] = []
    tol = spec.tolerance if spec.tolerance is not None else float(
        budget.get("tolerance", DEFAULT_TOLERANCE))
    counts = collective_counts(hlo_text)
    nbytes = collective_bytes(hlo_text)
    b_counts: Dict[str, int] = budget.get("collective_counts", {})
    b_bytes: Dict[str, int] = budget.get("collective_bytes", {})

    for kind, n in sorted(counts.items()):
        allowed = b_counts.get(kind)
        if allowed is None:
            findings.append(Finding(
                rule="hlo-collective-count-budget", severity=ERROR,
                target=spec.name, location=f"op kind {kind}",
                message=(f"{n} {kind} op(s) but the budget has none of this "
                         f"kind — a new collective appeared in the "
                         f"schedule")))
        elif n > allowed * (1.0 + tol) + 1:
            findings.append(Finding(
                rule="hlo-collective-count-budget", severity=ERROR,
                target=spec.name, location=f"op kind {kind}",
                message=(f"{n} {kind} ops vs budget {allowed} "
                         f"(+{(n / allowed - 1) * 100:.0f}%, tolerance "
                         f"{tol * 100:.0f}%)")))
    for kind, b in sorted(nbytes.items()):
        allowed = b_bytes.get(kind, 0)
        if b > allowed * (1.0 + tol) + _BYTES_SLACK:
            over = (f"+{(b / allowed - 1) * 100:.0f}%" if allowed
                    else "new kind")
            findings.append(Finding(
                rule="hlo-collective-bytes-budget", severity=ERROR,
                target=spec.name, location=f"op kind {kind}",
                message=(f"{b} collective bytes of {kind} vs budget "
                         f"{allowed} ({over}, tolerance {tol * 100:.0f}%)")))
    total, b_total = sum(nbytes.values()), sum(b_bytes.values())
    if total > b_total * (1.0 + tol) + _BYTES_SLACK:
        over = (f"+{(total / b_total - 1) * 100:.0f}%" if b_total
                else "empty budget")
        findings.append(Finding(
            rule="hlo-collective-bytes-budget", severity=ERROR,
            target=spec.name, location="total",
            message=(f"{total} total collective bytes vs budget {b_total} "
                     f"({over}, tolerance {tol * 100:.0f}%)")))
    elif b_total and total < b_total * (1.0 - tol) - _BYTES_SLACK:
        findings.append(Finding(
            rule="hlo-collective-bytes-budget", severity=WARNING,
            target=spec.name, location="total",
            message=(f"{total} total collective bytes is "
                     f"{(1 - total / b_total) * 100:.0f}% UNDER budget "
                     f"{b_total} — schedule improved; refresh with "
                     f"--update-budgets")))
    return findings


# -------------------------------------------------------------------- rules
def _check_f64(hlo_text: str, spec: HloCheckSpec) -> List[Finding]:
    findings = []
    for line_no, line in enumerate(hlo_text.splitlines(), start=1):
        if not _OP_LINE_RE.match(line):
            continue
        shape_part = line.split("=", 1)[1].split("(", 1)[0]
        if re.search(r"\bf64\[", shape_part):
            findings.append(Finding(
                rule="hlo-f64", severity=ERROR, target=spec.name,
                location=f"line {line_no}",
                message=(f"f64 op in the compiled program (weak-type "
                         f"promotion?): {line.strip()[:120]}")))
    return findings


def _check_host_transfer(hlo_text: str, spec: HloCheckSpec) -> List[Finding]:
    findings = []
    for line_no, line in enumerate(hlo_text.splitlines(), start=1):
        if not _OP_LINE_RE.match(line):
            continue
        m = re.match(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^=]*?\)|\S+)"
                     r"\s+([a-z0-9\-]+)\(", line)
        opname = m.group(1) if m else ""
        is_host = opname in _HOST_TRANSFER_OPS or (
            opname == "custom-call" and _CALLBACK_TARGET_RE.search(line))
        if is_host:
            findings.append(Finding(
                rule="hlo-host-transfer", severity=ERROR, target=spec.name,
                location=f"line {line_no}",
                message=(f"host transfer in the step hot path: "
                         f"{line.strip()[:120]}")))
    return findings


def _check_replicated(hlo_text: str, spec: HloCheckSpec) -> List[Finding]:
    findings = []
    for shape in spec.forbid_replicated:
        for line_no, line in enumerate(hlo_text.splitlines(), start=1):
            if _OP_LINE_RE.match(line) and shape in line:
                findings.append(Finding(
                    rule="hlo-replicated-egress", severity=ERROR,
                    target=spec.name, location=f"line {line_no}",
                    message=(f"forbidden replicated buffer {shape} "
                             f"materialized (param-sharded egress "
                             f"regression): {line.strip()[:120]}")))
                break  # one finding per forbidden shape is enough
    return findings


def _check_pallas(hlo_text: str, spec: HloCheckSpec,
                  backend: str) -> List[Finding]:
    if not spec.expect_pallas_custom_call or backend not in ("tpu", "gpu",
                                                             "cuda", "rocm"):
        return []
    if _PALLAS_TARGET_RE.search(hlo_text):
        return []
    return [Finding(
        rule="hlo-pallas-missing", severity=ERROR, target=spec.name,
        location="whole program",
        message=("use_kernels=True but no Pallas kernel custom-call in the "
                 "compiled program — silent jnp fallback"))]


def lint_hlo(hlo_text: str, spec: HloCheckSpec, backend: str = "cpu",
             budget_dir: Optional[str] = None) -> List[Finding]:
    """Run every HLO rule for one compiled program."""
    findings = (_check_f64(hlo_text, spec)
                + _check_host_transfer(hlo_text, spec)
                + _check_replicated(hlo_text, spec)
                + _check_pallas(hlo_text, spec, backend))
    if spec.check_budget:
        findings += _check_budget(
            hlo_text, spec,
            load_budget(spec.budget_name or spec.name, budget_dir))
    return findings
