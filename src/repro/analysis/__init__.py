"""Three-layer static-analysis gate (HLO lint / jaxpr lint / AST lint).

The paper's bucketing guarantee only holds if the implementation actually
runs the prescribed aggregation — and the failure mode is silent (PR 7:
``pallas_call`` quietly falling back to jnp on real meshes; a replicated
``[n_pad]`` egress inflating ICI traffic ~14x with every test green). This
package turns those hand-verified compiled-program invariants into an
executable regression gate:

  repro.analysis.hlo_lint    rules + collective count/byte budgets over
                             ``compiled.as_text()``
  repro.analysis.jaxpr_lint  rules over the closed jaxpr of the hot paths
  repro.analysis.ast_lint    Python AST rules over ``src/``
  repro.analysis.targets     the compiled programs the gate inspects
  repro.analysis.cli         ``python -m repro.analysis`` driver

Run ``python -m repro.analysis`` (or ``scripts/lint_repro.py``); see
``docs/static_analysis.md`` for every rule and the budget-file format.

This module imports neither jax nor the target code — the CLI must be able
to force the host device topology before jax's backend initializes.
"""

from repro.analysis.findings import ERROR, WARNING, Finding, Report

__all__ = ["ERROR", "WARNING", "Finding", "Report"]
