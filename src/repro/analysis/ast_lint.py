"""AST lint: custom Python source rules over ``src/``.

Rules (ids are what ``# lint: disable=...`` must name):

  ast-prng-reuse
      The same PRNG key expression is consumed by two random-consuming
      calls in one function scope without an intervening reassignment
      (``split``/``fold_in`` are key *derivers*, not consumers). This is
      the exact bug class PR 7 fixed in ``CrossDeviceSim.step``: the
      message-level attack shared the aggregator's key, correlating
      attacker randomness with the defense's resampling permutation.
      Consumers are ``jax.random.<sampler>(key, ...)`` calls and ANY call
      taking a ``key=`` / ``rng=`` keyword argument.

  ast-import-env-mutation
      Module-import-time mutation of process/backend state:
      ``os.environ[...] = ...`` (or ``.update``/``.setdefault``/``.pop``),
      ``os.putenv``, ``jax.config.update`` or ``jax.config.<attr> = ...``
      at module level (the ``launch/dryrun.py`` bug class — forcing 512
      host devices on whoever imports the module). Statements under an
      ``if __name__ == "__main__":`` guard are exempt, as is anything
      inside a function body.

  ast-mutable-default
      Mutable default argument (``def f(x, acc=[])``).

Suppression: append ``# lint: disable=<rule>[,<rule>...]`` (or
``disable=all``) to the flagged line.
"""

from __future__ import annotations

import ast
import os
import re
from typing import Dict, Iterable, List, Optional, Tuple

from repro.analysis.findings import ERROR, Finding

RULES = ("ast-prng-reuse", "ast-import-env-mutation", "ast-mutable-default")

# jax.random.* functions that DERIVE keys rather than consuming randomness.
_KEY_DERIVERS = frozenset(
    {"split", "fold_in", "PRNGKey", "key", "wrap_key_data", "key_data",
     "clone", "key_impl"})
# keyword names treated as "this call consumes this PRNG key"
_KEY_KWARGS = frozenset({"key", "rng", "rng_key", "prng_key"})

_SUPPRESS_RE = re.compile(r"#\s*lint:\s*disable=([\w\-,\s]+)")


# --------------------------------------------------------------- helpers
def _dotted(node: ast.AST) -> Optional[str]:
    """'os.environ' for Attribute(Name('os'), 'environ'); None otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _key_expr_id(node: ast.AST) -> Optional[Tuple]:
    """Stable identity for a trackable key expression (Name, Name[int],
    dotted attribute); None for calls/constants (untrackable)."""
    if isinstance(node, ast.Name):
        return ("name", node.id)
    if isinstance(node, ast.Subscript) and isinstance(node.value, ast.Name):
        idx = node.slice
        if isinstance(idx, ast.Constant):
            return ("sub", node.value.id, idx.value)
        return None
    dotted = _dotted(node)
    if dotted is not None:
        return ("attr", dotted)
    return None


def _base_name(expr_id: Tuple) -> str:
    if expr_id[0] == "attr":
        return expr_id[1].split(".")[0]
    return expr_id[1]


_SCOPE_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def _calls_in(node: ast.AST) -> Iterable[ast.Call]:
    """Call nodes in source order, NOT descending into nested scopes."""
    out: List[ast.Call] = []

    def rec(n: ast.AST) -> None:
        for child in ast.iter_child_nodes(n):
            if isinstance(child, _SCOPE_NODES + (ast.ClassDef,)):
                continue
            if isinstance(child, ast.Call):
                out.append(child)
            rec(child)

    rec(node)
    out.sort(key=lambda c: (c.lineno, c.col_offset))
    return out


def _assigned_names(stmt: ast.stmt) -> List[str]:
    """Base names (re)bound by this statement."""
    targets: List[ast.expr] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        targets = [stmt.target]
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        targets = [i.optional_vars for i in stmt.items if i.optional_vars]
    names: List[str] = []
    for t in targets:
        for n in ast.walk(t):
            if isinstance(n, ast.Name):
                names.append(n.id)
    return names


# ----------------------------------------------------------- PRNG reuse
class _PrngScope:
    """Linear statement walk of one function/module scope."""

    def __init__(self, filename: str):
        self.filename = filename
        self.findings: List[Finding] = []
        # expr id -> (first consumer line, call description)
        self.uses: Dict[Tuple, Tuple[int, str]] = {}

    def _consumers(self, call: ast.Call) -> List[Tuple[ast.AST, str]]:
        """(key expression node, call description) consumed by this call."""
        out: List[Tuple[ast.AST, str]] = []
        dotted = _dotted(call.func) or ""
        if dotted.startswith("jax.random.") or dotted.startswith("jrandom."):
            fn = dotted.rsplit(".", 1)[1]
            if fn in _KEY_DERIVERS:
                return []
            if call.args:
                out.append((call.args[0], dotted))
            for kw in call.keywords:
                if kw.arg in _KEY_KWARGS:
                    out.append((kw.value, dotted))
            return out
        for kw in call.keywords:
            if kw.arg in _KEY_KWARGS:
                out.append((kw.value, dotted or "<call>"))
        return out

    def _scan_calls(self, stmt: ast.stmt) -> None:
        for call in _calls_in(stmt):
            for key_node, desc in self._consumers(call):
                expr_id = _key_expr_id(key_node)
                if expr_id is None:
                    continue
                prev = self.uses.get(expr_id)
                if prev is not None:
                    first_line, first_desc = prev
                    self.findings.append(Finding(
                        rule="ast-prng-reuse", severity=ERROR,
                        target=self.filename,
                        location=f"{self.filename}:{key_node.lineno}",
                        message=(
                            f"PRNG key {ast.unparse(key_node)!r} consumed by "
                            f"{desc} was already consumed by {first_desc} at "
                            f"line {first_line} with no split/reassignment "
                            f"in between"),
                    ))
                else:
                    self.uses[expr_id] = (key_node.lineno, desc)

    def _reassign(self, stmt: ast.stmt) -> None:
        names = set(_assigned_names(stmt))
        if names:
            self.uses = {k: v for k, v in self.uses.items()
                         if _base_name(k) not in names}

    def walk(self, body: Iterable[ast.stmt]) -> None:
        for stmt in body:
            if isinstance(stmt, _SCOPE_NODES + (ast.ClassDef,)):
                continue  # nested scopes are scanned separately
            if isinstance(stmt, (ast.If, ast.Try)):
                self._scan_calls_shallow(stmt)
                entry = dict(self.uses)
                branches = []
                if isinstance(stmt, ast.If):
                    branches = [stmt.body, stmt.orelse]
                else:
                    branches = [stmt.body, stmt.orelse, stmt.finalbody]
                    branches += [h.body for h in stmt.handlers]
                for branch in branches:
                    self.uses = dict(entry)
                    self.walk(branch)
                # only one branch executes: don't carry branch-local uses
                # forward (conservative — avoids if/else false positives).
                self.uses = entry
                continue
            if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While,
                                 ast.With, ast.AsyncWith)):
                self._scan_calls_shallow(stmt)
                self._reassign(stmt)
                self.walk(stmt.body)
                self.walk(getattr(stmt, "orelse", []) or [])
                continue
            self._scan_calls(stmt)
            self._reassign(stmt)

    def _scan_calls_shallow(self, stmt: ast.stmt) -> None:
        """Scan only the header expression of a compound statement (the
        test / iterable / context managers), not its body."""
        headers: List[ast.AST] = []
        if isinstance(stmt, ast.If) or isinstance(stmt, ast.While):
            headers = [stmt.test]
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            headers = [stmt.iter]
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            headers = [i.context_expr for i in stmt.items]
        for h in headers:
            fake = ast.Expr(value=h)
            ast.copy_location(fake, h)
            self._scan_calls(fake)


def _prng_reuse(tree: ast.Module, filename: str) -> List[Finding]:
    findings: List[Finding] = []
    # module scope
    scope = _PrngScope(filename)
    scope.walk(tree.body)
    findings.extend(scope.findings)
    # every function scope, wherever nested
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fscope = _PrngScope(filename)
            fscope.walk(node.body)
            findings.extend(fscope.findings)
    return findings


# ------------------------------------------------- import-time env mutation
_ENV_MUTATORS = frozenset({"update", "setdefault", "pop", "popitem", "clear"})


def _is_main_guard(stmt: ast.stmt) -> bool:
    if not isinstance(stmt, ast.If):
        return False
    t = stmt.test
    return (isinstance(t, ast.Compare)
            and isinstance(t.left, ast.Name) and t.left.id == "__name__")


def _walk_no_scope(node: ast.AST) -> Iterable[ast.AST]:
    """node + descendants, never descending into function/lambda bodies."""
    stack = [node]
    while stack:
        n = stack.pop()
        yield n
        for c in ast.iter_child_nodes(n):
            if not isinstance(c, _SCOPE_NODES):
                stack.append(c)


def _env_mutation(tree: ast.Module, filename: str) -> List[Finding]:
    findings: List[Finding] = []

    def flag(node: ast.AST, what: str) -> None:
        findings.append(Finding(
            rule="ast-import-env-mutation", severity=ERROR, target=filename,
            location=f"{filename}:{node.lineno}",
            message=(f"{what} at module import time — move it behind an "
                     f"explicit activate()/main() guard (the dryrun.py bug "
                     f"class: import order silently decides process state)"),
        ))

    def check_tree(root: ast.AST) -> None:
        for node in _walk_no_scope(root):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.Delete)):
                targets = (node.targets if isinstance(node, (ast.Assign,
                                                             ast.Delete))
                           else [node.target])
                for t in targets:
                    if isinstance(t, ast.Subscript) and \
                            _dotted(t.value) == "os.environ":
                        flag(node, "os.environ[...] mutation")
                    elif isinstance(t, ast.Attribute) and \
                            (_dotted(t) or "").startswith("jax.config."):
                        flag(node, f"assignment to {_dotted(t)}")
            elif isinstance(node, ast.Call):
                dotted = _dotted(node.func) or ""
                if dotted.startswith("os.environ.") and \
                        dotted.rsplit(".", 1)[1] in _ENV_MUTATORS:
                    flag(node, f"{dotted}() mutation")
                elif dotted == "os.putenv":
                    flag(node, "os.putenv() mutation")
                elif dotted.startswith("jax.config."):
                    flag(node, f"{dotted}() call")

    def _headers(stmt: ast.stmt) -> List[ast.AST]:
        if isinstance(stmt, (ast.If, ast.While)):
            return [stmt.test]
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            return [stmt.iter]
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            return [i.context_expr for i in stmt.items]
        if isinstance(stmt, ast.ClassDef):
            return list(stmt.bases) + list(stmt.decorator_list)
        return []

    def visit_body(body: Iterable[ast.stmt]) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue  # function bodies run at call time, not at import
            if _is_main_guard(stmt):
                continue
            if isinstance(stmt, (ast.If, ast.Try, ast.For, ast.AsyncFor,
                                 ast.While, ast.With, ast.AsyncWith,
                                 ast.ClassDef)):
                for h in _headers(stmt):
                    check_tree(h)
                for sub in (getattr(stmt, "body", []),
                            getattr(stmt, "orelse", []),
                            getattr(stmt, "finalbody", []),
                            *[h.body for h in getattr(stmt, "handlers", [])]):
                    visit_body(sub)
            else:
                check_tree(stmt)

    visit_body(tree.body)
    return findings


# ------------------------------------------------------- mutable defaults
_MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
                     ast.SetComp)
_MUTABLE_CTORS = frozenset({"list", "dict", "set", "defaultdict"})


def _mutable_defaults(tree: ast.Module, filename: str) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        defaults = list(node.args.defaults) + [
            d for d in node.args.kw_defaults if d is not None]
        for d in defaults:
            bad = isinstance(d, _MUTABLE_LITERALS) or (
                isinstance(d, ast.Call) and isinstance(d.func, ast.Name)
                and d.func.id in _MUTABLE_CTORS)
            if bad:
                findings.append(Finding(
                    rule="ast-mutable-default", severity=ERROR,
                    target=filename,
                    location=f"{filename}:{d.lineno}",
                    message=(f"mutable default argument "
                             f"{ast.unparse(d)!r} in {node.name}() is shared "
                             f"across calls — default to None instead"),
                ))
    return findings


# ----------------------------------------------------------------- driver
def _suppressed_rules(source_line: str) -> frozenset:
    m = _SUPPRESS_RE.search(source_line)
    if not m:
        return frozenset()
    return frozenset(r.strip() for r in m.group(1).split(","))


def lint_source(source: str, filename: str = "<string>") -> List[Finding]:
    """All AST rules over one source string."""
    try:
        tree = ast.parse(source, filename=filename)
    except SyntaxError as e:
        return [Finding(rule="ast-syntax-error", severity=ERROR,
                        target=filename, location=f"{filename}:{e.lineno}",
                        message=str(e))]
    findings = (_prng_reuse(tree, filename)
                + _env_mutation(tree, filename)
                + _mutable_defaults(tree, filename))
    lines = source.splitlines()
    kept = []
    for f in findings:
        try:
            line_no = int(f.location.rsplit(":", 1)[1])
            suppressed = _suppressed_rules(lines[line_no - 1])
        except (IndexError, ValueError):
            suppressed = frozenset()
        if f.rule in suppressed or "all" in suppressed:
            continue
        kept.append(f)
    kept.sort(key=lambda f: f.location)
    return kept


def lint_paths(paths: Iterable[str]) -> List[Finding]:
    """All AST rules over every ``*.py`` file under the given paths."""
    findings: List[Finding] = []
    for root in paths:
        if os.path.isfile(root):
            files = [root]
        else:
            files = []
            for dirpath, dirnames, filenames in os.walk(root):
                dirnames[:] = [d for d in dirnames
                               if d not in ("__pycache__", ".git")]
                files.extend(os.path.join(dirpath, fn)
                             for fn in sorted(filenames)
                             if fn.endswith(".py"))
        for path in files:
            with open(path, "r", encoding="utf-8") as fh:
                findings.extend(lint_source(fh.read(), filename=path))
    return findings
