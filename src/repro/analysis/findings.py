"""Finding / report types shared by all three analysis layers.

A ``Finding`` is one rule violation: which rule fired, where (a source
``file:line`` for AST rules, an analysis-target name + HLO/jaxpr location
for the compiled layers), and severity. ``error`` findings make
``python -m repro.analysis`` exit nonzero; ``warning`` findings are
reported but do not gate.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List

ERROR = "error"
WARNING = "warning"


@dataclasses.dataclass
class Finding:
    rule: str        # rule id, e.g. "hlo-collective-bytes-budget"
    severity: str    # ERROR | WARNING
    target: str      # analysis target name, or source file for AST rules
    location: str    # "file:line", "line N: <hlo op>", jaxpr eqn, ...
    message: str

    def to_dict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return (f"[{self.severity}] {self.rule} @ {self.target} "
                f"({self.location}): {self.message}")


@dataclasses.dataclass
class Report:
    """Machine-readable result of one analysis run."""

    findings: List[Finding] = dataclasses.field(default_factory=list)
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def extend(self, findings: List[Finding]) -> None:
        self.findings.extend(findings)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == ERROR]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == WARNING]

    @property
    def ok(self) -> bool:
        return not self.errors

    def to_dict(self) -> Dict[str, object]:
        return {
            "ok": self.ok,
            "n_errors": len(self.errors),
            "n_warnings": len(self.warnings),
            "meta": self.meta,
            "findings": [f.to_dict() for f in self.findings],
        }

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    def summary(self) -> str:
        lines = [str(f) for f in self.findings]
        verdict = ("OK" if self.ok else "FAIL")
        lines.append(
            f"repro.analysis: {verdict} — {len(self.errors)} error(s), "
            f"{len(self.warnings)} warning(s)")
        return "\n".join(lines)
