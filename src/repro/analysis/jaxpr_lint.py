"""jaxpr lint: rules over the closed jaxpr of the hot-path functions.

The jaxpr is the layer where routing decisions are still visible as named
primitives (``pallas_call``, ``shard_map``, ``pure_callback``) before XLA
lowers them away — the right place to catch PR 7's failure mode, where
``use_kernels=True`` silently took the jnp route and nothing in the test
suite noticed. Rules:

  jaxpr-callback
      ``debug_callback`` / ``io_callback`` / ``pure_callback`` equation in
      the hot path — a host round-trip per step.

  jaxpr-f64
      An equation produces a float64/complex128 value (weak-type f32→f64
      promotion; only observable when x64 is enabled, but cheap to check
      everywhere).

  jaxpr-pallas-missing
      The function was built with ``use_kernels=True`` but its jaxpr
      contains NO ``pallas_call`` equation — the silent jnp fallback.
      Works on every backend, including CPU interpret mode, because the
      check runs before lowering erases the primitive.
"""

from __future__ import annotations

from typing import Any, Iterator, List

from repro.analysis.findings import ERROR, Finding

_CALLBACK_PRIMITIVES = frozenset(
    {"pure_callback", "io_callback", "debug_callback", "callback"})
_PALLAS_PRIMITIVES = frozenset({"pallas_call"})


def _sub_jaxprs(params: dict) -> Iterator[Any]:
    """Every Jaxpr/ClosedJaxpr hiding in an equation's params (pjit
    call_jaxpr, shard_map jaxpr, scan/while bodies, cond branches, ...)."""
    from jax.core import ClosedJaxpr, Jaxpr

    def rec(v):
        if isinstance(v, ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, Jaxpr):
            yield v
        elif isinstance(v, (list, tuple)):
            for item in v:
                yield from rec(item)
        elif isinstance(v, dict):
            for item in v.values():
                yield from rec(item)

    for v in params.values():
        yield from rec(v)


def iter_eqns(jaxpr: Any) -> Iterator[Any]:
    """All equations of a (closed) jaxpr, recursively through sub-jaxprs."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)  # ClosedJaxpr -> Jaxpr
    for eqn in inner.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def primitive_counts(jaxpr: Any) -> dict:
    out: dict = {}
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        out[name] = out.get(name, 0) + 1
    return out


def lint_jaxpr(jaxpr: Any, target: str,
               expect_pallas: bool = False) -> List[Finding]:
    """Run every jaxpr rule over one traced function."""
    import numpy as np

    findings: List[Finding] = []
    n_pallas = 0
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name in _PALLAS_PRIMITIVES:
            n_pallas += 1
        if name in _CALLBACK_PRIMITIVES:
            findings.append(Finding(
                rule="jaxpr-callback", severity=ERROR, target=target,
                location=f"{name} eqn",
                message=(f"{name} in the hot path — a host round-trip "
                         f"per step (params: "
                         f"{sorted(eqn.params)[:4]})")))
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            dtype = getattr(aval, "dtype", None)
            if dtype is not None and dtype in (np.float64, np.complex128):
                findings.append(Finding(
                    rule="jaxpr-f64", severity=ERROR, target=target,
                    location=f"{name} eqn",
                    message=(f"{name} produces {dtype} — weak-type f32→f64 "
                             f"promotion in the hot path")))
                break  # one finding per eqn
    if expect_pallas and n_pallas == 0:
        findings.append(Finding(
            rule="jaxpr-pallas-missing", severity=ERROR, target=target,
            location="whole jaxpr",
            message=("use_kernels=True but the traced jaxpr has no "
                     "pallas_call equation — the kernel route silently "
                     "fell back to jnp")))
    return findings
