"""``python -m repro.analysis`` — run all three static-analysis layers.

Layers (select with ``--layers``):
  ast    repo-wide Python AST rules over ``src/`` (no jax needed)
  jaxpr  rules over the closed jaxpr of each analysis target
  hlo    rules + collective budgets over each target's compiled HLO

The compiled layers run on a forced 8-device host platform (the same
topology as ``tests/test_shard_engine.py`` and the CI quick job): ``main``
prepends ``--xla_force_host_platform_device_count`` to ``XLA_FLAGS``
*inside the function, before jax's backend initializes* — an explicit
activation, not an import side effect (ast-import-env-mutation).

Exit status is nonzero iff any error-severity finding fired. ``--json``
writes the machine-readable report; ``--update-budgets`` regenerates the
committed per-target collective budgets from the current tree instead of
checking them.
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

from repro.analysis.findings import Report

DEFAULT_SRC = ("src",)
ALL_LAYERS = ("ast", "jaxpr", "hlo")


def _force_host_devices(n: int) -> None:
    """Force ``n`` host devices if jax has not locked its backend yet."""
    if "jax" in sys.modules:
        import jax

        if jax.device_count() >= n:
            return  # caller already provides the topology
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n} " + flags)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="three-layer static analysis gate (HLO / jaxpr / AST)")
    ap.add_argument("--layers", type=str, default="all",
                    help="comma list of ast,jaxpr,hlo (default: all)")
    ap.add_argument("--targets", type=str, default=None,
                    help="comma list of analysis targets (default: all)")
    ap.add_argument("--src", type=str, nargs="*", default=None,
                    help="paths for the AST layer (default: src)")
    ap.add_argument("--json", type=str, default=None,
                    help="write the machine-readable report here")
    ap.add_argument("--update-budgets", action="store_true",
                    help="regenerate committed collective budgets from the "
                         "current tree instead of checking them")
    ap.add_argument("--budget-dir", type=str, default=None,
                    help="override the budget directory (tests)")
    ap.add_argument("--tolerance", type=float, default=None,
                    help="override every budget file's tolerance")
    ap.add_argument("--devices", type=int, default=None,
                    help="forced host device count (default: what the "
                         "targets need)")
    args = ap.parse_args(argv)

    layers = (list(ALL_LAYERS) if args.layers == "all"
              else [l.strip() for l in args.layers.split(",") if l.strip()])
    unknown = [l for l in layers if l not in ALL_LAYERS]
    if unknown:
        ap.error(f"unknown layer(s) {unknown}; have {list(ALL_LAYERS)}")

    report = Report(meta={"layers": layers})

    # ---- AST layer: pure stdlib, runs first (and without jax)
    if "ast" in layers:
        from repro.analysis.ast_lint import lint_paths

        src = args.src if args.src is not None else list(DEFAULT_SRC)
        report.meta["ast_paths"] = src
        report.extend(lint_paths(src))

    # ---- compiled layers: force the host topology, then import jax
    if "jaxpr" in layers or "hlo" in layers:
        from repro.analysis import targets as targets_mod

        _force_host_devices(args.devices or targets_mod.N_DEVICES)
        import jax

        from repro.analysis.hlo_lint import (lint_hlo, make_budget,
                                             write_budget)
        from repro.analysis.jaxpr_lint import lint_jaxpr

        backend = jax.default_backend()
        report.meta.update(jax_version=jax.__version__, backend=backend,
                           n_devices=jax.device_count())
        names = (args.targets.split(",") if args.targets else None)
        built = targets_mod.build_targets(names)
        report.meta["targets"] = [t.name for t in built]
        for target in built:
            if "jaxpr" in layers:
                report.extend(lint_jaxpr(target.jaxpr, target.name,
                                         expect_pallas=target.expect_pallas))
            if "hlo" in layers:
                # Targets that check ANOTHER target's budget (budget_name
                # set — e.g. the telemetry-off exact-match proof) never own
                # a budget file: skip the write, keep the check live so
                # --update-budgets still verifies the cross-target invariant
                # against the freshly written reference budget.
                if args.update_budgets and target.spec.budget_name is None:
                    budget = make_budget(
                        target.hlo_text, target.name,
                        tolerance=(args.tolerance
                                   if args.tolerance is not None
                                   else None) or 0.25,
                        meta={"jax_version": jax.__version__,
                              "backend": backend,
                              "n_devices": jax.device_count(),
                              "description": target.description})
                    path = write_budget(budget, args.budget_dir)
                    print(f"wrote {path}")
                    target.spec.check_budget = False  # fresh by definition
                if args.tolerance is not None:
                    target.spec.tolerance = args.tolerance
                report.extend(lint_hlo(target.hlo_text, target.spec,
                                       backend=backend,
                                       budget_dir=args.budget_dir))

    if args.json:
        with open(args.json, "w", encoding="utf-8") as fh:
            fh.write(report.to_json())
            fh.write("\n")
    print(report.summary())
    return 0 if report.ok else 1
