"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape x mesh) combination:
  lower + compile the step (train_step for train shapes, forward for
  prefill, serve_step for decode), print memory_analysis / cost_analysis,
  and extract the roofline terms (compute / memory / collective — see
  EXPERIMENTS.md §Roofline). Collective bytes are parsed from the compiled
  HLO text (all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute operand sizes).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]

The 512 placeholder CPU devices exist ONLY inside ``main()``: ``activate()``
prepends ``--xla_force_host_platform_device_count`` to ``XLA_FLAGS`` and
MUST run before jax initializes its backend (jax locks the device count on
first init; importing jax does not initialize it). This used to happen at
module import time, which silently hijacked the device topology of ANY
importer — the bug class the ``ast-import-env-mutation`` rule of
``repro.analysis`` now rejects repo-wide. Importing this module has no side
effects; smoke tests / benchmarks see the real single device.
"""

import argparse
import os
import json
import sys
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import INPUT_SHAPES, ByzConfig, get_config, list_archs
from repro.configs.base import InputShape
from repro.distributed.steps import (
    batch_shardings,
    input_specs,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
from repro.launch.hlo_analysis import (  # noqa: F401 - re-exported
    COLLECTIVE_OPS,
    _DTYPE_BYTES,
    _parse_shape_bytes,
    collective_bytes,
)
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tfm

# TPU v5e hardware constants (assignment)
PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9       # bytes/s per chip
ICI_BW = 50e9        # bytes/s per link


def roofline_terms(flops: float, bytes_hbm: float, coll: Dict[str, int], n_chips: int):
    t_compute = flops / (n_chips * PEAK_FLOPS)
    t_memory = bytes_hbm / (n_chips * HBM_BW)
    total_coll = float(sum(coll.values()))
    t_coll = total_coll / (n_chips * ICI_BW)
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k] if k.endswith("_s") else -1)
    terms["collective_bytes"] = total_coll
    return terms


def dryrun_one(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    byz: Optional[ByzConfig] = None,
    verbose: bool = True,
    overrides: Optional[dict] = None,
    exact_costs: bool = True,
) -> Dict:
    """Lower + compile one (arch, shape, mesh) combination.

    ``exact_costs``: XLA's cost_analysis counts a ``lax.scan`` body ONCE
    regardless of trip count, so a depth-L model reports ~1-layer costs. We
    correct by compiling twice (scan_unroll=1 and 2) and extrapolating:
    cost(u) = fixed + u*period  =>  true = c1 + (n_periods-1)*(c2-c1).
    The multi-pod sweep (which only proves lowering/sharding) skips this.
    """
    cfg = get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    byz = byz or ByzConfig(
        aggregator="rfa", mixing="bucketing", s=2, worker_momentum=0.9, delta=0.1
    )

    # --- applicability gates (DESIGN.md §6)
    if shape.kind == "decode" and shape_name == "long_500k":
        if cfg.long_context == "window" and cfg.long_context_window <= 0:
            return {"skipped": "full-attention arch without window variant"}

    t0 = time.time()
    specs = input_specs(cfg, shape)

    def compile_variant(cfg_v):
        from repro.distributed.sharding import param_shardings

        b_sh = batch_shardings(cfg_v, shape, mesh)
        params_shape = jax.eval_shape(
            lambda: tfm.init_params(cfg_v, jax.random.PRNGKey(0)))
        params_sh = param_shardings(params_shape, mesh, fsdp=cfg_v.fsdp)
        t_start = time.time()
        with mesh:
            if shape.kind == "train":
                step_fn, sh = make_train_step(cfg_v, byz, mesh)
                rep = sh["replicated"]
                jitted = jax.jit(
                    step_fn,
                    in_shardings=(sh["params"], sh["opt_state"], sh["worker_m"],
                                  rep, b_sh),
                    out_shardings=(sh["params"], sh["opt_state"], sh["worker_m"],
                                   rep),
                )
                key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
                lowered = jitted.lower(sh["params_shape"], sh["opt_shape"],
                                       sh["wm_shape"], key_spec, specs)
            elif shape.kind == "prefill":
                prefill = make_prefill_step(cfg_v, mesh)
                jitted = jax.jit(prefill, in_shardings=(params_sh, b_sh))
                lowered = jitted.lower(params_shape, specs)
            else:  # decode
                from jax.sharding import NamedSharding, PartitionSpec as P

                serve, cache_shape, cache_sh = make_serve_step(cfg_v, mesh, shape)
                rep = NamedSharding(mesh, P())
                jitted = jax.jit(
                    serve,
                    in_shardings=(params_sh, cache_sh, b_sh["token"], rep),
                    out_shardings=(rep, cache_sh),
                )
                pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
                lowered = jitted.lower(params_shape, cache_shape, specs["token"],
                                       pos_spec)
            t_lower = time.time() - t_start
            t_c0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t_c0

        cost = compiled.cost_analysis()
        coll = collective_bytes(compiled.as_text())
        return {
            "compiled": compiled,
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll,
            "t_lower": t_lower,
            "t_compile": t_compile,
        }

    v1 = compile_variant(cfg)
    compiled = v1["compiled"]
    t_lower, t_compile = v1["t_lower"], v1["t_compile"]
    flops, bytes_hbm, coll = v1["flops"], v1["bytes"], dict(v1["coll"])

    # ---- scan-body cost extrapolation (see docstring)
    n_p = cfg.n_periods
    if exact_costs and n_p > 1:
        import dataclasses
        if n_p <= 8:
            # shallow scan: full unroll is affordable and EXACT (avoids the
            # failure mode where XLA CSE across unrolled periods makes
            # cost(unroll=2) < cost(unroll=1) and the extrapolation negative)
            v2 = compile_variant(dataclasses.replace(cfg, scan_unroll=n_p))
            flops, bytes_hbm, coll = v2["flops"], v2["bytes"], dict(v2["coll"])
        else:
            v2 = compile_variant(dataclasses.replace(cfg, scan_unroll=2))
            k = n_p - 1
            if v2["flops"] >= v1["flops"]:
                flops = v1["flops"] + k * (v2["flops"] - v1["flops"])
                bytes_hbm = max(v1["bytes"] + k * (v2["bytes"] - v1["bytes"]),
                                v1["bytes"])
                keys = set(v1["coll"]) | set(v2["coll"])
                coll = {
                    c: max(0.0, v1["coll"].get(c, 0) +
                           k * (v2["coll"].get(c, 0) - v1["coll"].get(c, 0)))
                    for c in keys
                }
            else:  # guard: fall back to body-times-trip-count upper proxy
                flops = v1["flops"] * n_p
                bytes_hbm = v1["bytes"] * n_p
                coll = {c: v * n_p for c, v in v1["coll"].items()}
        t_lower += v2["t_lower"]
        t_compile += v2["t_compile"]

    mem = compiled.memory_analysis()
    terms = roofline_terms(flops, bytes_hbm, coll, n_chips)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": shape.kind,
        "flops": flops,
        "bytes_hbm": bytes_hbm,
        "collectives": coll,
        **terms,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
    }
    try:
        result["bytes_per_device"] = {
            "argument": mem.argument_size_in_bytes,
            "output": mem.output_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "peak": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception:
        result["bytes_per_device"] = str(mem)

    if verbose:
        print(f"== {arch} x {shape_name} x {result['mesh']} ({shape.kind}) ==")
        print("memory_analysis:", result["bytes_per_device"])
        print(
            f"cost_analysis: flops={flops:.3e} bytes={bytes_hbm:.3e} "
            f"collective_bytes={terms['collective_bytes']:.3e}"
        )
        print(
            f"roofline: compute={terms['compute_s']*1e3:.2f}ms "
            f"memory={terms['memory_s']*1e3:.2f}ms "
            f"collective={terms['collective_s']*1e3:.2f}ms "
            f"-> bottleneck: {terms['bottleneck']}"
        )
        print(f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
    return result


def activate(n_devices: int = 512) -> None:
    """Force ``n_devices`` placeholder host devices. Must run before jax's
    backend initializes (first device query) — an explicit opt-in, NOT an
    import side effect."""
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices} "
        + os.environ.get("XLA_FLAGS", "")
    )


def main():
    activate()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", type=str, default=None)
    ap.add_argument("--agg", type=str, default="rfa")
    ap.add_argument("--mixing", type=str, default="bucketing")
    args = ap.parse_args()

    byz = ByzConfig(
        aggregator=args.agg, mixing=args.mixing, s=2, worker_momentum=0.9, delta=0.1
    )
    results = []
    if args.all:
        combos = [(a, s) for a in list_archs() for s in INPUT_SHAPES]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        combos = [(args.arch, args.shape)]

    for arch, shape in combos:
        try:
            results.append(dryrun_one(arch, shape, args.multi_pod, byz,
                                      exact_costs=not args.multi_pod))
        except Exception as e:  # noqa: BLE001 - report and continue the sweep
            print(f"!! {arch} x {shape} FAILED: {type(e).__name__}: {e}")
            results.append({"arch": arch, "shape": shape, "error": str(e)[:500]})

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    failed = [r for r in results if "error" in r]
    print(f"\n{len(results) - len(failed)}/{len(results)} combinations compiled")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
