"""Import-safe HLO text analysis helpers.

These used to live in ``repro.launch.dryrun``, but that module mutates
``XLA_FLAGS`` (forcing 512 host devices) at import time, so tests and
benchmarks could not reuse its parsers without hijacking their own device
topology. This module has NO import side effects: it only parses compiled
HLO text (``compiled.as_text()``).

  collective_bytes(hlo)  — per-op-kind byte totals of every collective
  _parse_shape_bytes(s)  — bytes of an HLO shape string like 'bf16[4,128]'
"""

from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "pred": 1, "s64": 8, "u64": 8,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
)


def _parse_shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'bf16[4,128]{1,0}' or a tuple."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        nbytes = _DTYPE_BYTES.get(dt)
        if nbytes is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * nbytes
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op in the HLO text."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(
            r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*?\)|[^\s]+)\s+([a-z\-]+)\(",
            stripped,
        )
        if not m:
            continue
        shape_str, opname = m.group(1), m.group(2)
        if opname in COLLECTIVE_OPS:
            key = opname.replace("-start", "")
            out[key] = out.get(key, 0) + _parse_shape_bytes(shape_str)
    return out
