"""Import-safe HLO text analysis helpers.

These used to live in ``repro.launch.dryrun``, but that module mutated
``XLA_FLAGS`` (forcing 512 host devices) at import time, so tests and
benchmarks could not reuse its parsers without hijacking their own device
topology. This module has NO import side effects: it only parses compiled
HLO text (``compiled.as_text()``).

  iter_collectives(hlo)    — (kind, bytes, line_no) for every collective,
                             async start/done pairs counted exactly once
  collective_bytes(hlo)    — per-op-kind byte totals of every collective
  collective_counts(hlo)   — per-op-kind op counts (pairs counted once)
  _parse_shape_bytes(s)    — bytes of an HLO shape string like 'bf16[4,128]'

The static-analysis rule engine (``repro.analysis.hlo_lint``) builds its
collective count/byte budget checks on top of these parsers.
"""

from __future__ import annotations

import re
from typing import Dict, Iterator, Tuple

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "pred": 1, "s64": 8, "u64": 8,
    # fp8 variants (all 1 byte)
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "f8e4m3b11fnuz": 1,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start", "all-gather-done", "all-reduce-done",
    "collective-permute-done",
)

# async pairs: the '-start' op's (tuple) shape holds both operand and result
# buffers, so counting it would roughly double the payload; the '-done' op's
# output shape IS the transferred result. We count each pair ONCE, at the
# '-done', and fall back to the '-start' only if its done never appears.
_ASYNC_SUFFIXES = ("-start", "-done")

# '%name = shape op(...operands...)' — group(1)=defined var, group(2)=shape
# (possibly a tuple '(...)'), group(3)=op name, group(4)=operand list.
_OP_RE = re.compile(
    r"(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*?\)|[^\s]+)\s+([a-z0-9\-]+)\((.*)"
)


def _parse_shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'bf16[4,128]{1,0}' or a tuple."""
    total = 0
    for m in re.finditer(r"([a-z]\w*)\[([\d,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        nbytes = _DTYPE_BYTES.get(dt)
        if nbytes is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * nbytes
    return total


def _base_kind(opname: str) -> str:
    for suf in _ASYNC_SUFFIXES:
        if opname.endswith(suf):
            return opname[: -len(suf)]
    return opname


def iter_collectives(hlo_text: str) -> Iterator[Tuple[str, int, int]]:
    """Yield ``(kind, payload_bytes, line_no)`` for every collective op.

    Async ``-start``/``-done`` pairs are yielded exactly once (at the
    ``-done``, whose output shape is the transferred payload); an unpaired
    ``-start`` (no matching done in the text) is yielded with its own shape.
    """
    # pass 1: collect op records and remember which start vars have a done.
    records = []  # (var, opname, shape_bytes, operands, line_no)
    done_operands = set()
    for line_no, line in enumerate(hlo_text.splitlines(), start=1):
        m = _OP_RE.match(line.strip())
        if not m:
            continue
        var, shape_str, opname, rest = m.groups()
        if opname not in COLLECTIVE_OPS:
            continue
        operands = tuple(re.findall(r"%?([\w.\-]+)", rest.split(")", 1)[0]))
        records.append((var, opname, _parse_shape_bytes(shape_str), operands,
                       line_no))
        if opname.endswith("-done"):
            done_operands.update(operands)
    # pass 2: yield, skipping starts whose done was seen.
    for var, opname, nbytes, operands, line_no in records:
        if opname.endswith("-start") and var in done_operands:
            continue
        yield _base_kind(opname), nbytes, line_no


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum payload bytes of every collective op in the HLO text, keyed by
    base op kind (start/done pairs counted exactly once)."""
    out: Dict[str, int] = {}
    for kind, nbytes, _ in iter_collectives(hlo_text):
        out[kind] = out.get(kind, 0) + nbytes
    return out


def collective_counts(hlo_text: str) -> Dict[str, int]:
    """Count collective ops per base kind (start/done pairs counted once)."""
    out: Dict[str, int] = {}
    for kind, _, _ in iter_collectives(hlo_text):
        out[kind] = out.get(kind, 0) + 1
    return out
