"""Mesh factories.

``make_production_mesh`` builds the assigned production meshes:
single-pod (16, 16) over ("data", "model") — 256 chips — and multi-pod
(2, 16, 16) over ("pod", "data", "model") — 512 chips. It is a FUNCTION so
importing this module never touches jax device state; the dry-run driver
sets XLA_FLAGS for 512 placeholder devices before any jax import.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host/CPU) devices exist — used by
    sharding-semantics tests with xla_force_host_platform_device_count."""
    return jax.make_mesh((data, model), ("data", "model"))


def worker_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def n_workers(mesh) -> int:
    out = 1
    for a in worker_axes(mesh):
        out *= mesh.shape[a]
    return out
