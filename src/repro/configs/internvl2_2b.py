"""InternVL2-2B language decoder (InternLM2-1.8B arch) [arXiv:2404.16821].

The InternViT-300M vision encoder + MLP projector are STUBS per the
assignment: ``input_specs`` supplies 256 precomputed patch embeddings per
image consumed as a prefix before the text tokens.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    n_prefix_tokens=256,
    mlp_kind="swiglu",
    long_context="window",
    long_context_window=8192,
    source="arXiv:2404.16821",
)
