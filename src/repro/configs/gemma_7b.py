"""Gemma-7B — GeGLU, head_dim 256, tied embeddings [arXiv:2403.08295].

(The 2B sibling uses MQA; the assigned 7B uses kv=16 = MHA.)
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    mlp_kind="geglu",
    tie_embeddings=True,
    fsdp=True,
    # the tied embed doubles as the LM head: keep d_model on the model axis
    # (the layout every block activation already has) and FSDP the 256k
    # vocab rows over data — the inferred rule would pick the reverse
    # (vocab over model), forcing a d_model all-to-all around every logits
    # matmul. Exercised + asserted in tests/test_steps.py.
    sharding_overrides=(("^embed$", ("data", "model")),),
    momentum_mode="server",
    remat="full",
    long_context="window",
    long_context_window=8192,
    source="arXiv:2403.08295",
)
