"""Qwen1.5-32B — dense, QKV bias, full MHA (kv=40) [hf:Qwen/Qwen1.5-0.5B]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    mlp_kind="swiglu",
    fsdp=True,
    momentum_mode="server",
    remat="full",
    long_context="window",
    long_context_window=8192,
    source="hf:Qwen/Qwen1.5-0.5B",
)
