"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full (paper-scale) ModelConfig;
``smoke_config(name)`` returns the reduced same-family variant used by the
CPU smoke tests (<=2 layers / one pattern period, d_model <= 512,
<= 4 experts).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import (
    INPUT_SHAPES,
    ByzConfig,
    InputShape,
    MeshConfig,
    ModelConfig,
    TrainConfig,
)


def _load_all() -> Dict[str, ModelConfig]:
    from repro.configs import (
        gemma_7b,
        internvl2_2b,
        jamba_v0_1_52b,
        kimi_k2_1t_a32b,
        mamba2_130m,
        musicgen_medium,
        olmoe_1b_7b,
        paper_mnist,
        qwen1_5_32b,
        qwen2_5_14b,
        tinyllama_1_1b,
    )

    mods = [
        musicgen_medium,
        tinyllama_1_1b,
        mamba2_130m,
        internvl2_2b,
        olmoe_1b_7b,
        kimi_k2_1t_a32b,
        jamba_v0_1_52b,
        qwen1_5_32b,
        qwen2_5_14b,
        gemma_7b,
        paper_mnist,
    ]
    return {m.CONFIG.name: m.CONFIG for m in mods}


_CONFIGS: Dict[str, ModelConfig] = {}


def get_config(name: str) -> ModelConfig:
    global _CONFIGS
    if not _CONFIGS:
        _CONFIGS = _load_all()
    if name not in _CONFIGS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_CONFIGS)}")
    return _CONFIGS[name]


def list_archs(include_paper: bool = False) -> List[str]:
    global _CONFIGS
    if not _CONFIGS:
        _CONFIGS = _load_all()
    out = sorted(n for n in _CONFIGS if n != "paper-mnist-mlp")
    if include_paper:
        out.append("paper-mnist-mlp")
    return out


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family variant: <= 2 layers (one short period for the
    hybrid), d_model <= 512, <= 4 experts — runs a forward/train step on CPU."""
    cfg = get_config(name)
    ch: Dict = dict(
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 0,
        d_ff=512,
        vocab_size=512,
        head_dim=64 if cfg.head_dim else 0,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        long_context_window=256,
        dtype="float32",  # CPU smoke tests check numerics in fp32
        remat="none",
    )
    if cfg.n_experts:
        ch.update(
            n_experts=4,
            experts_per_token=2,
            d_ff_expert=128,
            n_shared_experts=min(cfg.n_shared_experts, 1),
        )
    if cfg.family in ("ssm", "hybrid"):
        ch.update(ssm_state=32, ssm_head_dim=32, ssm_chunk=16)
    if cfg.pattern:  # hybrid: shrink to a 2-layer period keeping both mixers
        ch["pattern"] = (("ssm", "moe"), ("attn", "mlp"))
        ch["n_layers"] = 2
    if cfg.n_prefix_tokens:
        ch["n_prefix_tokens"] = 8
    return dataclasses.replace(cfg, **ch)


__all__ = [
    "ModelConfig",
    "ByzConfig",
    "MeshConfig",
    "TrainConfig",
    "InputShape",
    "INPUT_SHAPES",
    "get_config",
    "smoke_config",
    "list_archs",
]
