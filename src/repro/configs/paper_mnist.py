"""The paper's own experimental model (MNIST MLP; App. Table 5)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paper-mnist-mlp",
    family="mlp",
    n_layers=2,
    d_model=128,
    n_heads=0,
    n_kv_heads=0,
    d_ff=128,
    vocab_size=10,  # classes
    dtype="float32",
    source="ICLR2022 bucketing paper, App. A.1.1",
)
