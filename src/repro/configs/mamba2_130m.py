"""Mamba2-130m — SSD (state-space duality), attention-free [arXiv:2405.21060].

d_inner = 2*768 = 1536, 24 SSD heads of dim 64, state N=128. Decode keeps an
O(1) recurrent state, so long_500k runs natively (long_context="state").
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=64,
    conv_kernel=4,
    tie_embeddings=True,
    long_context="state",
    source="arXiv:2405.21060",
)
