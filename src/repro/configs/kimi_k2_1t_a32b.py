"""Kimi K2 — trillion-parameter MoE, 32B active (paper-table entry)
[arXiv:2501.kimi2].

384 experts top-8 + 1 shared expert, 61 layers, d_model 7168, GQA kv=8 with
head_dim 128 (we use GQA per the assignment; K2's MLA is out of scope).
Uses server momentum (paper Remark 7) + FSDP: per-worker momentum state at
1T params is infeasible (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab_size=163840,
    n_experts=384,
    experts_per_token=8,
    d_ff_expert=2048,
    n_shared_experts=1,
    mlp_kind="swiglu",
    fsdp=True,
    momentum_mode="server",
    opt_m_dtype="bfloat16",  # fp32 momentum (16 GB/chip) cannot fit v5e
    remat="full",
    long_context="window",
    long_context_window=8192,
    source="arXiv:2501.kimi2",
)
