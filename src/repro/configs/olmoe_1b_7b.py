"""OLMoE-1B-7B — 64 experts, top-8 routing, 1B active params [arXiv:2409.02060]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    n_experts=64,
    experts_per_token=8,
    d_ff_expert=1024,
    mlp_kind="swiglu",
    long_context="window",
    long_context_window=8192,
    source="arXiv:2409.02060",
)
