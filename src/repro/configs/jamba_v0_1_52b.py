"""Jamba v0.1 — 52B hybrid Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887].

Period-8 pattern: one attention layer per 8 (offset 4), MoE every other
layer. Jamba v0.1 uses Mamba-1 (d_state 16); we implement the Mamba-2/SSD
form with N=16 (DESIGN.md §7). Only 4 attention layers -> the full-length
KV cache at batch 1 is small even at 500k, so long_context="full".
"""

from repro.configs.base import ModelConfig

_PERIOD = (
    ("ssm", "mlp"),
    ("ssm", "moe"),
    ("ssm", "mlp"),
    ("ssm", "moe"),
    ("attn", "mlp"),
    ("ssm", "moe"),
    ("ssm", "mlp"),
    ("ssm", "moe"),
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    pattern=_PERIOD,
    n_experts=16,
    experts_per_token=2,
    d_ff_expert=14336,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=64,
    mlp_kind="swiglu",
    fsdp=True,
    momentum_mode="server",
    remat="full",
    long_context="full",
    source="arXiv:2403.19887",
)
