"""TinyLlama 1.1B — llama2-architecture small model [arXiv:2401.02385]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    mlp_kind="swiglu",
    rope_theta=10000.0,
    long_context="window",
    long_context_window=8192,
    source="arXiv:2401.02385",
)
