"""MusicGen-medium decoder backbone over EnCodec tokens [arXiv:2306.05284].

The EnCodec conv codec / T5 text conditioner are STUBS per the assignment:
``input_specs`` supplies 4 parallel codebook token streams (vocab 2048 each,
summed embeddings, per-codebook output heads — the flattened/delay codebook
interleave pattern collapses to this backbone) plus 64 precomputed
conditioning embeddings consumed as a prefix (we use prefix conditioning in
place of MusicGen's cross-attention; see DESIGN.md §7).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    n_codebooks=4,
    n_prefix_tokens=64,
    mlp_kind="gelu",
    qkv_bias=False,
    long_context="window",
    long_context_window=8192,
    source="arXiv:2306.05284",
)
