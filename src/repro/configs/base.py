"""Config system.

``ModelConfig`` describes every assigned architecture declaratively; the
generic pattern-scanned transformer in ``repro/models/transformer.py``
consumes it. ``ByzConfig`` configures the paper's technique; ``MeshConfig``
and ``TrainConfig`` configure the distributed runtime.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# (mixer_kind, ff_kind) per layer within one period.
#   mixer_kind in {"attn", "ssm"}; ff_kind in {"mlp", "moe", "none"}.
LayerSpec = Tuple[str, str]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio | mlp
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // n_heads

    # --- layer pattern (repeated every `period` layers). Empty => derived.
    pattern: Tuple[LayerSpec, ...] = ()

    # --- MoE
    n_experts: int = 0
    experts_per_token: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3

    # --- SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    conv_kernel: int = 4

    # --- attention details
    qkv_bias: bool = False
    mlp_kind: str = "swiglu"  # swiglu | geglu | gelu
    rope_theta: float = 10000.0
    sliding_window: int = 0  # 0 = full attention
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    attention_impl: str = "auto"  # auto | xla | blockwise
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    logit_softcap: float = 0.0  # gemma-style final-logit softcap (0 = off)

    # --- multimodal stubs (frontends NOT implemented per assignment)
    n_prefix_tokens: int = 0  # vlm patch embeds / audio conditioning prefix
    n_codebooks: int = 0      # musicgen EnCodec codebooks (0 = plain LM)

    # --- numerics / memory
    dtype: str = "bfloat16"
    opt_m_dtype: str = "float32"  # optimizer momentum storage (bf16 for 1T)
    remat: str = "none"  # none | full
    scan_unroll: int = 1  # >1 (or = n_periods) unrolls the layer scan —
    #                       used by the dry-run for exact HLO cost analysis
    fsdp: bool = False    # shard params over the data axis too
    # per-arch sharding overrides replacing the inferred rule for matching
    # param paths: ((path_regex, spec_entries), ...) where each spec entry
    # is a mesh-axis name, a tuple of axis names, or None — decoded to
    # PartitionSpec by sharding.overrides_from_config. Nested tuples (not a
    # dict) so the frozen config stays hashable.
    sharding_overrides: Tuple[Tuple[str, Tuple], ...] = ()
    # momentum bookkeeping mode for Byzantine training (DESIGN.md §5)
    momentum_mode: str = "worker"  # worker (Alg. 2) | server (Remark 7)

    # --- long-context policy for the long_500k decode shape
    #   "full"    : keep the full-length KV cache (SSM / small-cache archs)
    #   "window"  : sliding-window KV cache (dense archs)
    #   "state"   : O(1) recurrent state only (pure SSM)
    long_context: str = "window"
    long_context_window: int = 8192

    source: str = ""  # citation

    # ------------------------------------------------------------ derived
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def pattern_(self) -> Tuple[LayerSpec, ...]:
        if self.pattern:
            return self.pattern
        if self.family == "ssm":
            return (("ssm", "none"),)
        if self.family == "moe" or (self.n_experts > 0):
            return (("attn", "moe"),)
        return (("attn", "mlp"),)

    @property
    def period(self) -> int:
        return len(self.pattern_)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % self.period == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern period {self.period}"
        )
        return self.n_layers // self.period

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        H, KV, dh = self.n_heads, self.n_kv_heads, self.head_dim_
        total = V * D  # embeddings
        if self.n_codebooks:
            total = self.n_codebooks * V * D
        n_mlp_mats = {"swiglu": 3, "geglu": 3, "gelu": 2}[self.mlp_kind]
        per_kind = {}
        per_kind["attn"] = D * (H * dh) + 2 * D * (KV * dh) + (H * dh) * D + (
            (H + 2 * KV) * dh if self.qkv_bias else 0
        )
        per_kind["mlp"] = n_mlp_mats * D * F
        if self.n_experts:
            Fe = self.d_ff_expert or F
            per_kind["moe"] = (
                D * self.n_experts
                + self.n_experts * n_mlp_mats * D * Fe
                + self.n_shared_experts * n_mlp_mats * D * Fe
            )
        if self.family in ("ssm", "hybrid"):
            Din, N, Hs = self.d_inner, self.ssm_state, self.ssm_heads
            G = 1
            conv_ch = Din + 2 * G * N
            per_kind["ssm"] = (
                D * (2 * Din + 2 * G * N + Hs)  # in_proj (z,x,B,C,dt)
                + conv_ch * self.conv_kernel
                + Hs * 2  # A_log, D skip
                + Din     # gated norm
                + Din * D  # out_proj
            )
        per_kind["none"] = 0
        for mixer, ff in self.pattern_:
            total += (per_kind[mixer] + per_kind.get(ff, 0) + 2 * D) * self.n_periods
        total += D  # final norm
        if not self.tie_embeddings:
            total += D * V * max(1, self.n_codebooks or 1)
        return total

    def active_param_count(self) -> int:
        """Params active per token (MoE: top-k experts only)."""
        if not self.n_experts:
            return self.param_count()
        n_mlp_mats = {"swiglu": 3, "geglu": 3, "gelu": 2}[self.mlp_kind]
        Fe = self.d_ff_expert or self.d_ff
        inactive = (
            (self.n_experts - self.experts_per_token)
            * n_mlp_mats
            * self.d_model
            * Fe
        )
        n_moe_layers = sum(1 for _, ff in self.pattern_ if ff == "moe") * self.n_periods
        return self.param_count() - inactive * n_moe_layers


@dataclasses.dataclass(frozen=True)
class ByzConfig:
    """The paper's technique, as a first-class training feature."""

    aggregator: str = "mean"        # mean | krum | cm | rfa | cclip | tm
    mixing: str = "none"            # none | bucketing | resampling | fixed_grouping
    s: int = 2                      # mixing factor (Alg. 1)
    delta: float = 0.0              # assumed Byzantine fraction
    worker_momentum: float = 0.9    # beta of Alg. 2 (0 = off)
    momentum_convention: str = "ema"
    cclip_tau: float = 10.0         # base clipping radius, scaled per App. A.2.1
    cclip_tau_scaling: str = "linear"
    attack: str = "none"
    attack_kwargs: tuple = ()
    n_byzantine: int = 0

    def make_aggregator(self, n_workers: int):
        from repro.core.aragg import RobustAggregator
        from repro.core.momentum import cclip_radius

        kwargs = {}
        if self.aggregator == "cclip":
            kwargs["tau"] = cclip_radius(
                self.worker_momentum, self.cclip_tau, self.cclip_tau_scaling
            )
        if self.aggregator == "krum":
            kwargs["n_byzantine"] = self.n_byzantine
        if self.aggregator == "tm":
            kwargs["n_trim"] = max(1, self.n_byzantine)
        return RobustAggregator.from_spec(
            self.aggregator,
            mixing=self.mixing,
            s=self.s,
            delta=self.delta,
            n_workers=n_workers,
            **kwargs,
        )


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def n_devices(self) -> int:
        out = 1
        for s in self.shape:
            out *= s
        return out

    @property
    def worker_axes(self) -> Tuple[str, ...]:
        """Mesh axes that enumerate Byzantine 'workers' (= DP groups)."""
        return tuple(a for a in self.axes if a in ("pod", "data"))


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    byz: ByzConfig = dataclasses.field(default_factory=ByzConfig)
    mesh: MeshConfig = dataclasses.field(default_factory=MeshConfig)
    seq_len: int = 4096
    global_batch: int = 256
    lr: float = 1e-3
    weight_decay: float = 0.0
    optimizer: str = "sgdm"  # sgdm | adamw
    beta1: float = 0.9
    beta2: float = 0.95
    steps: int = 100
    seed: int = 0
