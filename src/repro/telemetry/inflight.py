"""``InflightMetrics`` — the functional accumulator threaded through jits.

The accumulator is a trace-time object: inside a jitted function it holds
traced arrays; the dict it hands back (``tree()``) becomes ordinary extra
outputs of the compiled program. Nothing here performs a host callback or a
collective — every recorded value must already be replicated (coefficient-
space vectors, scalars) or is the caller's responsibility to keep cheap.

Zero-overhead-when-off: a disabled accumulator records nothing AND never
evaluates lazily-provided values, so guarding a probe as

    tm.put("cclip_clip_frac", lambda: jnp.mean(lam < 1.0, axis=1))

adds literally no equations to the off-trace. The off program is the seed
program (machine-checked — see repro.analysis's telemetry-off target).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Mapping, Union

from repro.telemetry import registry

Value = Union[Any, Callable[[], Any]]


class InflightMetrics:
    """Device-resident metrics pytree accumulated inside a traced function."""

    __slots__ = ("enabled", "_vals")

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._vals: Dict[str, Any] = {}

    def __bool__(self) -> bool:
        return self.enabled

    def put(self, name: str, value: Value) -> None:
        """Record one metric. ``value`` may be a zero-arg callable that is
        ONLY invoked when telemetry is enabled (the zero-overhead guard)."""
        if not self.enabled:
            return
        registry.get_metric(name)  # refuse names missing from the catalogue
        self._vals[name] = value() if callable(value) else value

    def update(self, stats: Union[Mapping[str, Any], None]) -> None:
        """Merge a probe's stats dict (e.g. an aggregator's)."""
        if not self.enabled or not stats:
            return
        for k, v in stats.items():
            self.put(k, v)

    def tree(self) -> Dict[str, Any]:
        """The metrics pytree to return out of the jit (empty when off)."""
        return dict(self._vals)

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        state = "on" if self.enabled else "off"
        return f"InflightMetrics({state}, {sorted(self._vals)})"
