"""In-graph telemetry engine: device-resident robustness metrics, phase
tracing, and structured run logs.

The paper's central claim — bucketing restores robust-aggregator guarantees
under heterogeneity — is only *observable* through quantities the hot paths
compute anyway: clip fractions and radii (CCLIP), Weiszfeld residuals (RFA),
Krum selection scores, trim masks (TM), and per-bucket dispersion.
Time-coupled attacks (ALIE, IPM, mimic) are diagnosed by watching these
statistics drift across rounds. This package makes them first-class:

  registry.py   metric catalogue: every metric the probes may emit, with
                phase / shape-kind / doc — the JSONL schema is validated
                against it.
  inflight.py   ``InflightMetrics`` — the functional accumulator threaded
                through the jitted hot paths. Metrics are ordinary device
                arrays riding OUT of the graph as extra outputs (no host
                callbacks, no extra collectives on the off path) and are
                drained asynchronously host-side.
  probes.py     the probe math shared by the stacked and packed engines
                (trim masks, per-bucket dispersion, worker deviation).
  profiling.py  ``phase()`` markers (jax.named_scope + TraceAnnotation) on
                pack -> gram -> mix -> kernel -> unpack, and the one-call
                ``trace_capture`` jax.profiler helper.
  events.py     host-side JSONL structured event log + ring-buffered step
                timing.

Zero-overhead-when-off contract: with ``telemetry=False`` (the default
everywhere) the traced program is IDENTICAL to the pre-telemetry seed —
bit-exact outputs, byte-identical collective budgets. This is machine-
checked by the ``sync_telemetry_off_rfa_bucketing`` analysis target
(``python -m repro.analysis``), which compares the telemetry-off compile
against the committed base budget with ZERO tolerance. See
docs/observability.md.
"""

from repro.telemetry.events import EventLog, RingTimer, validate_event, validate_jsonl
from repro.telemetry.inflight import InflightMetrics
from repro.telemetry.profiling import phase, trace_capture
from repro.telemetry.registry import MetricSpec, catalogue, get_metric, register

__all__ = [
    "EventLog",
    "InflightMetrics",
    "MetricSpec",
    "RingTimer",
    "catalogue",
    "get_metric",
    "phase",
    "register",
    "trace_capture",
    "validate_event",
    "validate_jsonl",
]
