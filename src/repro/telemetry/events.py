"""Host-side structured run logs: JSONL events + ring-buffered step timing.

One event per line, every line a JSON object with at least:

  {"kind": <str>, "t": <float unix seconds>, ...payload}

Kinds and their payloads (validated by ``validate_event``):

  run_meta   {"run_id", "meta": {...}}            — once, first line
  round      {"round": int, "metrics": {...}}     — per training round;
             metric names must be registered in the catalogue
  bench_row  {"bench", "cell": {...}, "stats": {"mean_us", ...}}
  probe      {"name", "data": {...}}              — scripts/coll_probe.py rows
  serve      {"metrics": {...}}                   — serving engine snapshots

The same writer backs the benchmark harness, the collective probe script and
the simulators, so every producer shares one schema (``validate_jsonl`` is
what the CI telemetry-smoke job runs against the sim's output).
"""

from __future__ import annotations

import collections
import json
import math
import os
import time
from typing import Any, Dict, IO, List, Mapping, Optional, Union

import numpy as np

from repro.telemetry import registry

EVENT_KINDS = ("run_meta", "round", "bench_row", "probe", "serve")

_REQUIRED: Dict[str, tuple] = {
    "run_meta": ("run_id", "meta"),
    "round": ("round", "metrics"),
    "bench_row": ("bench", "cell", "stats"),
    "probe": ("name", "data"),
    "serve": ("metrics",),
}


def _jsonable(x: Any) -> Any:
    """Coerce numpy / jax scalars and arrays into plain JSON values."""
    if isinstance(x, Mapping):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, (bool, int, float, str)) or x is None:
        return x
    if isinstance(x, (np.bool_, np.integer)):
        return int(x)
    if isinstance(x, np.floating):
        return float(x)
    arr = np.asarray(x)
    if arr.ndim == 0:
        return _jsonable(arr.item())
    return [_jsonable(v) for v in arr.tolist()]


class EventLog:
    """Append-only JSONL event writer.

    ``path=None`` keeps events in memory only (``.events``) — handy in tests
    and for producers that want the rows without touching disk."""

    def __init__(self, path: Optional[Union[str, os.PathLike]] = None,
                 run_id: Optional[str] = None):
        self.path = os.fspath(path) if path is not None else None
        self.run_id = run_id
        self.events: List[Dict[str, Any]] = []
        self._fh: Optional[IO[str]] = None
        if self.path is not None:
            os.makedirs(os.path.dirname(os.path.abspath(self.path)),
                        exist_ok=True)
            self._fh = open(self.path, "a", encoding="utf-8")

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "EventLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- emission ----------------------------------------------------------
    def emit(self, kind: str, **payload: Any) -> Dict[str, Any]:
        event = {"kind": kind, "t": time.time()}
        event.update(_jsonable(payload))
        validate_event(event)
        self.events.append(event)
        if self._fh is not None:
            self._fh.write(json.dumps(event) + "\n")
            self._fh.flush()
        return event

    def run_meta(self, **meta: Any) -> Dict[str, Any]:
        return self.emit("run_meta", run_id=self.run_id or "run", meta=meta)

    def round(self, round_idx: int, metrics: Mapping[str, Any]) -> Dict[str, Any]:
        return self.emit("round", round=int(round_idx), metrics=metrics)

    def bench_row(self, bench: str, cell: Mapping[str, Any],
                  stats: Mapping[str, Any]) -> Dict[str, Any]:
        return self.emit("bench_row", bench=bench, cell=cell, stats=stats)

    def probe(self, name: str, data: Mapping[str, Any]) -> Dict[str, Any]:
        return self.emit("probe", name=name, data=data)

    def serve(self, metrics: Mapping[str, Any]) -> Dict[str, Any]:
        return self.emit("serve", metrics=metrics)


# -- validation ------------------------------------------------------------
def validate_event(event: Mapping[str, Any]) -> None:
    """Raise ``ValueError`` if ``event`` does not satisfy the schema."""
    if not isinstance(event, Mapping):
        raise ValueError(f"event must be an object, got {type(event).__name__}")
    kind = event.get("kind")
    if kind not in EVENT_KINDS:
        raise ValueError(f"unknown event kind {kind!r} (expected {EVENT_KINDS})")
    t = event.get("t")
    if not isinstance(t, (int, float)) or isinstance(t, bool) or not math.isfinite(t):
        raise ValueError(f"event 't' must be a finite number, got {t!r}")
    missing = [k for k in _REQUIRED[kind] if k not in event]
    if missing:
        raise ValueError(f"{kind} event missing fields {missing}")
    if kind == "round":
        if not isinstance(event["round"], int) or isinstance(event["round"], bool):
            raise ValueError(f"round must be an int, got {event['round']!r}")
        metrics = event["metrics"]
        if not isinstance(metrics, Mapping):
            raise ValueError("round 'metrics' must be an object")
        for name in metrics:
            if not registry.is_registered(name):
                raise ValueError(
                    f"round metric {name!r} is not in the telemetry catalogue")
    if kind == "serve":
        metrics = event["metrics"]
        if not isinstance(metrics, Mapping):
            raise ValueError("serve 'metrics' must be an object")
        for name in metrics:
            if not registry.is_registered(name):
                raise ValueError(
                    f"serve metric {name!r} is not in the telemetry catalogue")


def validate_jsonl(path: Union[str, os.PathLike]) -> List[Dict[str, Any]]:
    """Parse + validate every line of a JSONL event file; return the events.

    Raises ``ValueError`` naming the offending line on the first failure."""
    events: List[Dict[str, Any]] = []
    with open(path, "r", encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, start=1):
            line = line.strip()
            if not line:
                continue
            try:
                event = json.loads(line)
                validate_event(event)
            except (json.JSONDecodeError, ValueError) as e:
                raise ValueError(f"{path}:{lineno}: {e}") from None
            events.append(event)
    return events


# -- step timing -----------------------------------------------------------
class RingTimer:
    """Ring-buffered wall-clock step timer (``perf_counter`` based).

    Keeps the last ``capacity`` durations; ``summary()`` reports count /
    mean / percentiles over the window, so a long run's statistics track
    recent behaviour instead of averaging over warmup."""

    def __init__(self, capacity: int = 256):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self._buf = collections.deque(maxlen=capacity)
        self._t0: Optional[float] = None
        self.total = 0       # durations ever recorded (not just in window)

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        if self._t0 is None:
            raise RuntimeError("RingTimer.stop() without start()")
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self.record(dt)
        return dt

    def record(self, seconds: float) -> None:
        self._buf.append(float(seconds))
        self.total += 1

    def __enter__(self) -> "RingTimer":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    def __len__(self) -> int:
        return len(self._buf)

    def summary(self) -> Dict[str, float]:
        if not self._buf:
            return {"count": 0}
        arr = np.asarray(self._buf, dtype=np.float64)
        return {
            "count": int(arr.size),
            "total": int(self.total),
            "mean_s": float(arr.mean()),
            "p50_s": float(np.percentile(arr, 50)),
            "p90_s": float(np.percentile(arr, 90)),
            "max_s": float(arr.max()),
        }
