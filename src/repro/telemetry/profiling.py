"""Phase markers and one-call profiler trace capture.

``phase("pack")`` wraps a region in both ``jax.named_scope`` (annotates the
jaxpr/HLO so ops carry ``telemetry/pack`` in their op_name metadata) and
``jax.profiler.TraceAnnotation`` (a named span on the host trace timeline).
Neither changes the computation: named_scope touches only metadata, so the
collective budgets checked by ``repro.analysis`` are unaffected — which is
why the markers are always on, even with ``telemetry=False``.

``trace_capture`` is the one-call helper: run any callable under
``jax.profiler.start_trace`` / ``stop_trace`` with the result blocked on, so
the captured timeline actually contains the compute. View the output with
TensorBoard or Perfetto (``docs/observability.md``).
"""

from __future__ import annotations

import contextlib
from typing import Any, Callable

import jax

_PREFIX = "telemetry"


@contextlib.contextmanager
def phase(name: str):
    """Mark a pipeline phase (pack / gram / mix / kernel / unpack / ...).

    Safe both inside a trace (named_scope annotates the jaxpr) and outside
    (TraceAnnotation shows up as a span when a profiler trace is active;
    otherwise both are cheap no-ops)."""
    scoped = f"{_PREFIX}/{name}"
    with jax.named_scope(scoped), jax.profiler.TraceAnnotation(scoped):
        yield


def trace_capture(logdir: str, fn: Callable[..., Any], *args: Any,
                  **kwargs: Any) -> Any:
    """Run ``fn(*args, **kwargs)`` under a jax profiler trace.

    Blocks on the result before stopping the trace so asynchronously
    dispatched device work is inside the capture window. Returns ``fn``'s
    result; the trace lands under ``logdir`` (open with TensorBoard's
    profile plugin or Perfetto)."""
    jax.profiler.start_trace(logdir)
    try:
        out = fn(*args, **kwargs)
        out = jax.block_until_ready(out)
    finally:
        jax.profiler.stop_trace()
    return out
