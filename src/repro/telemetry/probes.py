"""Probe math shared by the stacked and packed aggregation paths.

These functions compute *diagnostic* quantities from intermediates the hot
path already holds. They are only traced when telemetry is ON — on the off
path they are never called, so they may use conveniences (``jnp.sort``)
that would be banned from the always-on hot path. On a multi-device mesh
their column reductions compile to GSPMD psums; that added traffic exists
only in telemetry-on programs (the off-budget invariant is unaffected).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp


def bucket_dispersion(mixed: jnp.ndarray,
                      n_eff: Optional[int] = None) -> jnp.ndarray:
    """``||y_i - mean_j y_j||^2`` per mixed row, from the stacked buffer.

    ``n_eff`` divides nothing here (squared distances are sums, not means)
    but is accepted for signature symmetry with the other probes."""
    del n_eff
    x = mixed.astype(jnp.float32)
    centered = x - jnp.mean(x, axis=0, keepdims=True)
    return jnp.sum(jnp.square(centered), axis=1)


def bucket_dispersion_from_gram(gram_y: jnp.ndarray) -> jnp.ndarray:
    """Same quantity from the mixed Gram matrix (the factorized path):
    ``||y_i - ybar||^2 = G_ii - 2 mean_j G_ij + mean_jk G_jk``."""
    g = gram_y.astype(jnp.float32)
    row_mean = jnp.mean(g, axis=1)
    return jnp.diagonal(g) - 2.0 * row_mean + jnp.mean(row_mean)


def cm_worker_dev(mixed: jnp.ndarray, median: jnp.ndarray,
                  n_eff: Optional[int] = None) -> jnp.ndarray:
    """Mean |y_i - median| per input row.

    The ALIE signature: honest rows deviate ~0.8 sigma per coordinate from
    the median while ALIE rows sit at |z| sigma (z ~= 0.25-0.4) — Byzantine
    rows are suspiciously CLOSE to the median. ``n_eff`` corrects the mean
    for zero-padded packed-buffer columns (pad columns contribute 0 to the
    sum but would dilute a plain mean)."""
    x = mixed.astype(jnp.float32)
    dev = jnp.sum(jnp.abs(x - median[None, :].astype(jnp.float32)), axis=1)
    return dev / float(n_eff if n_eff else mixed.shape[1])


def tm_trim_frac(mixed: jnp.ndarray, n_trim: int,
                 n_eff: Optional[int] = None) -> jnp.ndarray:
    """Fraction of coordinates where row i fell inside a trimmed band — the
    compressed trim mask. A row is trimmed at a coordinate when its value is
    strictly below the b-th smallest kept value or strictly above the b-th
    largest kept value (ties with the band edge count as kept, matching the
    mean-of-the-sorted-band semantics of ``trimmed_mean_select``)."""
    x = mixed.astype(jnp.float32)
    W = x.shape[0]
    b = min(int(n_trim), (W - 1) // 2)
    if b == 0:
        return jnp.zeros((W,), jnp.float32)
    srt = jnp.sort(x, axis=0)
    lo, hi = srt[b], srt[W - 1 - b]
    mask = (x < lo[None, :]) | (x > hi[None, :])
    frac = jnp.sum(mask.astype(jnp.float32), axis=1)
    return frac / float(n_eff if n_eff else mixed.shape[1])


def coordinatewise_stats(base, mixed: jnp.ndarray, out: jnp.ndarray,
                         n_eff: Optional[int] = None) -> Dict[str, jnp.ndarray]:
    """Stats for a coordinatewise rule given the mixed stack and aggregate.

    ``base`` is the aggregator (``cm`` / ``tm`` get rule-specific masks;
    every rule gets per-bucket dispersion)."""
    stats = {"bucket_dispersion": bucket_dispersion(mixed)}
    if base.name == "cm":
        stats["cm_worker_dev"] = cm_worker_dev(mixed, out, n_eff)
    elif base.name == "tm":
        stats["tm_trim_frac"] = tm_trim_frac(mixed, base.n_trim, n_eff)
    return stats
