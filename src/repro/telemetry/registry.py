"""Metric registry — the catalogue of everything the probes may emit.

A metric is registered once, at import time, with its phase (which part of
the pipeline produces it), shape kind, and a one-line doc. ``InflightMetrics``
refuses to record unregistered names, so the catalogue in
docs/observability.md cannot silently drift from the code, and the JSONL
schema validator (``events.validate_event``) can check that a ``round``
event only carries known metrics.

Shape kinds (the trailing axes; a host-side series stacks rounds in front):

  scalar       ``[]``
  per_worker   ``[W]``        one value per worker row (pre-mixing)
  per_bucket   ``[m]``        one value per mixed row (post-bucketing)
  per_iter     ``[T]``        one value per inner-loop iteration
  per_iter_bucket ``[T, m]``  inner-loop series of per-bucket values
  counter      static host-side int (bytes, sizes — constants of the layout)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

KINDS = ("scalar", "per_worker", "per_bucket", "per_iter", "per_iter_bucket",
         "counter")
PHASES = ("aggregate", "sync", "train", "sim", "serve", "bench", "probe")


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    name: str
    phase: str   # one of PHASES
    kind: str    # one of KINDS
    doc: str

    def __post_init__(self):
        if self.phase not in PHASES:
            raise ValueError(f"unknown phase {self.phase!r} for {self.name}")
        if self.kind not in KINDS:
            raise ValueError(f"unknown kind {self.kind!r} for {self.name}")


_REGISTRY: Dict[str, MetricSpec] = {}


def register(name: str, phase: str, kind: str, doc: str) -> MetricSpec:
    spec = MetricSpec(name, phase, kind, doc)
    existing = _REGISTRY.get(name)
    if existing is not None and existing != spec:
        raise ValueError(f"metric {name!r} already registered as {existing}")
    _REGISTRY[name] = spec
    return spec


def get_metric(name: str) -> MetricSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unregistered metric {name!r} — add it to "
            f"repro/telemetry/registry.py (and docs/observability.md)"
        ) from None


def is_registered(name: str) -> bool:
    return name in _REGISTRY


def catalogue() -> Tuple[MetricSpec, ...]:
    """All registered metrics, name-sorted (the docs table / JSONL schema)."""
    return tuple(_REGISTRY[k] for k in sorted(_REGISTRY))


# --------------------------------------------------------------- aggregate
# RFA (smoothed Weiszfeld)
register("rfa_resid_norms", "aggregate", "per_iter_bucket",
         "residual norms ||v_t - y_i|| per Weiszfeld iteration")
register("rfa_residual", "aggregate", "per_iter",
         "geometric-median objective sum_i ||v_t - y_i|| per iteration")
register("rfa_iters", "aggregate", "counter", "Weiszfeld iteration count T")

# CCLIP / ACClip
register("cclip_lam", "aggregate", "per_iter_bucket",
         "clip weights min(1, tau/||y_i - v_t||) per iteration")
register("cclip_clip_frac", "aggregate", "per_iter",
         "fraction of inputs clipped (lam < 1) per iteration")
register("cclip_tau", "aggregate", "per_iter",
         "clipping radius per iteration (constant for CCLIP, "
         "median-adaptive for ACClip)")

# Krum
register("krum_scores", "aggregate", "per_bucket",
         "Krum score: summed sq-distance to the n-f-2 nearest neighbours")
register("krum_selected", "aggregate", "scalar",
         "index of the minimum-score (selected) input")

# coordinatewise rules
register("cm_worker_dev", "aggregate", "per_bucket",
         "mean |y_i - median| per input — ALIE rows sit suspiciously "
         "CLOSE to the median (see docs/observability.md)")
register("tm_trim_frac", "aggregate", "per_bucket",
         "fraction of coordinates where input i fell in a trimmed band "
         "(the compressed trim mask)")

# composition-level
register("worker_weights", "aggregate", "per_worker",
         "final per-worker combination weights M^T c")
register("bucket_dispersion", "aggregate", "per_bucket",
         "||y_i - mean_j y_j||^2 per mixed row — the dispersion bucketing "
         "is supposed to shrink by s")

# -------------------------------------------------------------------- sync
register("sync_n_workers", "sync", "counter", "worker rows W entering the sync")
register("sync_n_params", "sync", "counter", "true parameter count")
register("sync_n_pad", "sync", "counter", "padded packed-buffer columns")
register("sync_ingress_bytes", "sync", "counter",
         "packed-buffer ingress payload W * n_pad * 4")
register("sync_egress_bytes", "sync", "counter",
         "egress payload: n_pad*4 replicated, n_params*4 param-sharded")

# ------------------------------------------------------------- train / sim
register("loss", "train", "scalar", "mean worker training loss")
register("agg_norm", "sim", "scalar", "L2 norm of the robust aggregate")
register("grad_norm_mean", "sim", "scalar", "mean per-worker gradient norm")
register("byz_mask", "sim", "per_worker",
         "ground-truth Byzantine mask of this round's rows (simulation only)")
register("zeta_sq", "sim", "scalar",
         "empirical inter-worker gradient heterogeneity of the good workers")
register("byz_in_cohort", "sim", "scalar",
         "Byzantine clients sampled into this round's cohort")

# ------------------------------------------------------------------- serve
register("serve_queue_depth", "serve", "scalar", "requests waiting for a slot")
register("serve_active_slots", "serve", "scalar", "slots decoding a request")
register("serve_tokens_total", "serve", "counter", "tokens generated so far")
register("serve_steps_total", "serve", "counter", "engine decode steps so far")
register("serve_admit_latency_s", "serve", "scalar",
         "submit -> slot admission latency (seconds)")
register("serve_decode_step_s", "serve", "scalar",
         "wall time of one engine decode step (seconds)")
register("serve_tokens_per_s", "serve", "scalar",
         "generation throughput over the ring-buffer window")
