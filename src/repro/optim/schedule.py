"""Learning-rate schedules (plain callables step -> lr)."""

from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp


def constant_lr(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_lr(lr: float, total_steps: int, min_frac: float = 0.1) -> Callable:
    def f(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(math.pi * t)))

    return f


def warmup_cosine_lr(
    lr: float, total_steps: int, warmup_steps: int = 100, min_frac: float = 0.1
) -> Callable:
    cos = cosine_lr(lr, max(total_steps - warmup_steps, 1), min_frac)

    def f(step):
        warm = lr * jnp.minimum(step / max(warmup_steps, 1), 1.0)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))

    return f
