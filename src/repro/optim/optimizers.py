"""Hand-rolled optimizers (no optax in the offline container).

Both return ``(new_params, new_state)`` and keep their state as plain
pytrees so the distributed runtime can shard them like parameters. SGD-M is
the framework default for Byzantine training (it is Algorithm 2's server-
side update when worker momentum is active, and the Remark-7 server
momentum otherwise); AdamW is provided for standard LLM pretraining runs.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any  # first moment / momentum
    v: Any  # second moment (None for sgdm)


# ------------------------------------------------------------------ SGD-M
def sgdm_init(params, m_dtype=jnp.float32) -> OptState:
    """``m_dtype``: momentum storage dtype. bfloat16 halves optimizer-state
    HBM (the fit-enabling lever for the 1T kimi-k2 config — DESIGN.md §5);
    the update still accumulates in fp32."""
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, m_dtype), params),
        v=None,
    )


def sgdm_update(
    grads, state: OptState, params, lr: float, beta: float = 0.9, weight_decay: float = 0.0
) -> Tuple[Any, OptState]:
    m = jax.tree_util.tree_map(
        lambda mi, g: (beta * mi.astype(jnp.float32) + g.astype(jnp.float32))
        .astype(mi.dtype),
        state.m,
        grads,
    )
    def upd(p, mi):
        delta = lr * mi.astype(jnp.float32)
        if weight_decay:
            delta = delta + lr * weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - delta).astype(p.dtype)
    new_params = jax.tree_util.tree_map(upd, params, m)
    return new_params, OptState(state.step + 1, m, None)


# ------------------------------------------------------------------ AdamW
def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
    )


def adamw_update(
    grads,
    state: OptState,
    params,
    lr: float,
    beta1: float = 0.9,
    beta2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Tuple[Any, OptState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - beta1**t
    bc2 = 1.0 - beta2**t
    m = jax.tree_util.tree_map(
        lambda mi, g: beta1 * mi + (1 - beta1) * g.astype(jnp.float32), state.m, grads
    )
    v = jax.tree_util.tree_map(
        lambda vi, g: beta2 * vi + (1 - beta2) * jnp.square(g.astype(jnp.float32)),
        state.v,
        grads,
    )

    def upd(p, mi, vi):
        delta = lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
        if weight_decay:
            delta = delta + lr * weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - delta).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, OptState(step, m, v)


def make_optimizer(name: str, **hp) -> Tuple[Callable, Callable]:
    """Returns (init_fn(params), update_fn(grads, state, params) -> (params, state))."""
    name = name.lower()
    m_dtype = jnp.dtype(hp.get("m_dtype", "float32"))
    if name in ("sgdm", "sgd"):
        beta = hp.get("beta1", 0.9) if name == "sgdm" else 0.0
        def init(params):
            return sgdm_init(params, m_dtype=m_dtype)
        def update(g, s, p, lr=hp.get("lr", 1e-3)):
            return sgdm_update(g, s, p, lr, beta, hp.get("weight_decay", 0.0))
        return init, update
    if name == "adamw":
        def update(g, s, p, lr=hp.get("lr", 1e-3)):
            return adamw_update(
                g, s, p, lr,
                hp.get("beta1", 0.9), hp.get("beta2", 0.95),
                hp.get("eps", 1e-8), hp.get("weight_decay", 0.0),
            )
        return adamw_init, update
    raise KeyError(f"unknown optimizer {name!r}")
