from repro.optim.optimizers import (
    OptState,
    adamw_init,
    adamw_update,
    make_optimizer,
    sgdm_init,
    sgdm_update,
)
from repro.optim.schedule import constant_lr, cosine_lr, warmup_cosine_lr

__all__ = [
    "OptState",
    "sgdm_init",
    "sgdm_update",
    "adamw_init",
    "adamw_update",
    "make_optimizer",
    "constant_lr",
    "cosine_lr",
    "warmup_cosine_lr",
]
