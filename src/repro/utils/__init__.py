from repro.utils.tree import (
    tree_add,
    tree_axpy,
    tree_dot,
    tree_global_norm,
    tree_scale,
    tree_size,
    tree_stack_flat,
    tree_sub,
    tree_unstack_flat,
    tree_zeros_like,
)

__all__ = [
    "tree_add",
    "tree_axpy",
    "tree_dot",
    "tree_global_norm",
    "tree_scale",
    "tree_size",
    "tree_stack_flat",
    "tree_sub",
    "tree_unstack_flat",
    "tree_zeros_like",
]
