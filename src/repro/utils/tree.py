"""Pytree arithmetic helpers used throughout the framework.

These are deliberately tiny, jit-friendly wrappers over ``jax.tree_util`` so
that optimizer / aggregator code reads like vector algebra.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(a, scalar):
    return jax.tree_util.tree_map(lambda x: x * scalar, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y, leafwise."""
    return jax.tree_util.tree_map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_dot(a, b):
    """Global dot product across all leaves (fp32 accumulation)."""
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    return sum(
        jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32))
        for x, y in zip(leaves_a, leaves_b)
    )


def tree_global_norm(tree):
    """Global L2 norm across all leaves (fp32 accumulation)."""
    leaves = jax.tree_util.tree_leaves(tree)
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    return jnp.sqrt(sq)


def tree_size(tree) -> int:
    """Total number of scalar parameters in the tree (static)."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def tree_stack_flat(tree):
    """Flatten every leaf and concatenate into a single 1-D vector.

    Returns (vector, unflatten_fn). Used by the *simulation* path where the
    whole model fits on one host; the distributed path never materializes
    this (see repro.distributed.robust_sync).
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [x.shape for x in leaves]
    sizes = [int(jnp.size(x)) for x in leaves]
    flat = jnp.concatenate([jnp.ravel(x) for x in leaves]) if leaves else jnp.zeros((0,))

    def unflatten(vec):
        out, off = [], 0
        for shape, size in zip(shapes, sizes):
            out.append(jnp.reshape(vec[off : off + size], shape))
            off += size
        return jax.tree_util.tree_unflatten(treedef, out)

    return flat, unflatten


def tree_unstack_flat(vec, like_tree):
    """Inverse of tree_stack_flat given a template tree."""
    _, unflatten = tree_stack_flat(like_tree)
    return unflatten(vec)
