"""Cross-device federated learning mode (paper Remark 7).

In cross-device FL, thousands of clients are sampled online and never seen
twice, so clients CANNOT carry worker momentum (Algorithm 2's m_i). The
paper's Remark 7: send raw gradients, robust-aggregate with an agnostic
ARAGG, and apply *server* momentum to the aggregate — Theorem IV still
guarantees convergence when local variance is small / the model is
overparameterized.

``CrossDeviceSim`` simulates a client pool of ``n_clients`` with a
``byz_frac`` fraction Byzantine; each round samples ``clients_per_round``
uniformly, runs the message-level attack over the sampled cohort, mixes +
robust-aggregates, then applies server momentum and the SGD step.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ByzConfig
from repro.core.attacks import get_attack
from repro.distributed.packing import packed_aggregate
from repro.training.byzantine import stack_flatten_workers, unflatten_like


class CrossDeviceState(NamedTuple):
    params: Any
    server_m: jnp.ndarray  # [d] server momentum (Remark 7)
    step: jnp.ndarray


@dataclasses.dataclass(eq=False)
class CrossDeviceSim:
    loss_fn: Callable           # (params, x, y) -> scalar, one client batch
    byz: ByzConfig
    n_clients: int              # pool size
    byz_frac: float             # fraction of the POOL that is Byzantine
    clients_per_round: int
    lr: float = 0.1
    batch_size: int = 32
    server_momentum: float = 0.9
    #: surface the packed engine's device-resident metrics pytree in the
    #: step metrics / run history. Baked into the jit trace via static
    #: ``self`` — one trace per sim instance either way, so telemetry-on
    #: runs do NOT retrace per round (tests/test_telemetry.py).
    telemetry: bool = False

    def __post_init__(self):
        self.aggregator = self.byz.make_aggregator(self.clients_per_round)
        self.attack = get_attack(self.byz.attack, **dict(self.byz.attack_kwargs))
        self.n_byz_pool = int(self.byz_frac * self.n_clients)
        self.grad_fn = jax.grad(self.loss_fn)

    def init_state(self, params) -> CrossDeviceState:
        d = sum(x.size for x in jax.tree_util.tree_leaves(params))
        return CrossDeviceState(
            params=params,
            server_m=jnp.zeros((d,), jnp.float32),
            step=jnp.zeros((), jnp.int32),
        )

    @partial(jax.jit, static_argnums=0)
    def step(self, state: CrossDeviceState, data_x, data_y, key) -> Tuple[
            CrossDeviceState, Dict]:
        k_sample, k_batch, k_attack, k_agg = jax.random.split(key, 4)
        # sample a cohort (with replacement — simple and unbiased)
        cohort = jax.random.randint(
            k_sample, (self.clients_per_round,), 0, self.n_clients)
        byz_mask = cohort < self.n_byz_pool

        m = data_x.shape[1]
        idx = jax.random.randint(k_batch, (self.clients_per_round,
                                           self.batch_size), 0, m)
        bx = data_x[cohort[:, None], idx]
        by = data_y[cohort[:, None], idx]

        grads = jax.vmap(self.grad_fn, in_axes=(None, 0, 0))(state.params, bx, by)
        g_flat = stack_flatten_workers(grads).astype(jnp.float32)

        # attacks are stateless here (no persistent cohort across rounds).
        # k_attack is dedicated: feeding the aggregator's key to the attack
        # would correlate attacker randomness with the defense's resampling
        # permutation — an accidentally permutation-aware adversary.
        sent, _ = self.attack(g_flat, byz_mask, None, key=k_attack)
        # the cohort stack is already flat, so the packed engine applies
        # directly: kernel-routed mixing + rule on one padded buffer.
        if self.telemetry:
            agg, info = packed_aggregate(sent, self.aggregator, key=k_agg,
                                         telemetry=True, with_info=True)
        else:
            agg = packed_aggregate(sent, self.aggregator, key=k_agg)
            info = {}

        # Remark 7: SERVER momentum on the robust aggregate
        beta = self.server_momentum
        server_m = jnp.where(state.step == 0, agg,
                             beta * state.server_m + (1.0 - beta) * agg)

        new_params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) - self.lr * u).astype(p.dtype),
            state.params,
            unflatten_like(server_m, state.params),
        )
        metrics = {
            "byz_in_cohort": jnp.sum(byz_mask),
            "agg_norm": jnp.linalg.norm(agg),
        }
        if self.telemetry:
            tmtree = dict(info.get("telemetry", {}))
            tmtree["byz_mask"] = byz_mask
            tmtree["byz_in_cohort"] = metrics["byz_in_cohort"]
            tmtree["agg_norm"] = metrics["agg_norm"]
            metrics["telemetry"] = tmtree
        return CrossDeviceState(new_params, server_m, state.step + 1), metrics

    def run(self, params0, data_x, data_y, n_rounds: int, key,
            eval_fn: Optional[Callable] = None, eval_every: int = 50):
        """Run ``n_rounds``. Returns ``(state, history)``; with
        ``telemetry=True`` the history additionally carries
        ``history["telemetry"]`` — each registered metric stacked across
        rounds into one numpy array with a leading round axis. Device
        metrics are kept as jax arrays during the loop (async dispatch is
        never blocked mid-run) and converted once at the end."""
        import numpy as np

        state = self.init_state(params0)
        history: Dict[str, Any] = {"round": [], "eval": []}
        per_round: Dict[str, list] = {}
        for t in range(n_rounds):
            key, sub = jax.random.split(key)
            state, metrics = self.step(state, data_x, data_y, sub)
            if self.telemetry:
                for name, v in metrics["telemetry"].items():
                    per_round.setdefault(name, []).append(v)
            if eval_fn is not None and ((t + 1) % eval_every == 0
                                        or t == n_rounds - 1):
                history["round"].append(t + 1)
                history["eval"].append(float(eval_fn(state.params)))
        if self.telemetry:
            history["telemetry"] = {
                name: np.stack([np.asarray(v) for v in vs])
                for name, vs in per_round.items()
            }
        return state, history
