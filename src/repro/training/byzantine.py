"""Byzantine-robust training loop (Algorithm 2) — simulation path.

Simulates ``n`` workers on one host: per-worker gradients via ``vmap``,
worker momentum, message-level attacks, mixing + robust aggregation, server
update. Workers ``[0, f)`` are Byzantine (convention used by the attack
masks and the partitioner).

The distributed path (workers = mesh DP groups) lives in
``repro/distributed/robust_sync.py`` and reuses the same aggregator objects.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ByzConfig
from repro.core.attacks import get_attack
from repro.data.pipeline import sample_worker_batches


class SimState(NamedTuple):
    params: Any
    momentum: jnp.ndarray          # [W, d] worker momentum (flattened)
    attack_state: Any
    step: jnp.ndarray


def stack_flatten_workers(tree) -> jnp.ndarray:
    """Stacked grad tree (leaves [W, ...]) -> [W, d]."""
    leaves = jax.tree_util.tree_leaves(tree)
    W = leaves[0].shape[0]
    return jnp.concatenate([x.reshape(W, -1) for x in leaves], axis=1)


def unflatten_like(vec: jnp.ndarray, tree) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out, off = [], 0
    for leaf in leaves:
        size = leaf.size
        out.append(vec[off : off + size].reshape(leaf.shape).astype(leaf.dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclasses.dataclass(eq=False)  # identity hash => usable as a jit static arg
class ByzantineSim:
    """Paper-experiment harness.

    Args:
        loss_fn: (params, x, y) -> scalar loss for ONE worker batch.
        byz: ByzConfig (aggregator, mixing, attack, momentum, delta ...).
        n_workers: total workers n.
        n_byzantine: f (workers [0, f) are Byzantine).
        lr: server step size eta.
        batch_size: per-worker batch size.
    """

    loss_fn: Callable
    byz: ByzConfig
    n_workers: int
    n_byzantine: int
    lr: float = 0.01
    batch_size: int = 32
    #: surface the aggregator's device-resident stats (clip fractions,
    #: Weiszfeld residuals, Krum scores, trim masks — repro/telemetry) in
    #: the step metrics and run history. Static via ``self``: no signature
    #: change, one trace per instance, seed numerics when False.
    telemetry: bool = False

    def __post_init__(self):
        self.aggregator = self.byz.make_aggregator(self.n_workers)
        self.attack = get_attack(self.byz.attack, **dict(self.byz.attack_kwargs))
        self.byz_mask = jnp.arange(self.n_workers) < self.n_byzantine
        self.grad_fn = jax.grad(self.loss_fn)

    # ------------------------------------------------------------- states
    def init_state(self, params) -> SimState:
        d = sum(x.size for x in jax.tree_util.tree_leaves(params))
        return SimState(
            params=params,
            momentum=jnp.zeros((self.n_workers, d), jnp.float32),
            attack_state=self.attack.init_state(self.n_workers, d),
            step=jnp.zeros((), jnp.int32),
        )

    # --------------------------------------------------------------- step
    @partial(jax.jit, static_argnums=0)
    def step(self, state: SimState, data_x, data_y, key) -> Tuple[SimState, Dict]:
        k_batch, k_attack, k_agg = jax.random.split(key, 3)
        bx, by = sample_worker_batches(k_batch, data_x, data_y, self.batch_size)

        # per-worker gradients (vmap over the worker axis)
        grads = jax.vmap(self.grad_fn, in_axes=(None, 0, 0))(state.params, bx, by)
        g_flat = stack_flatten_workers(grads).astype(jnp.float32)  # [W, d]

        # worker momentum (Algorithm 2); step 0 initializes m = g
        beta = self.byz.worker_momentum
        if self.byz.momentum_convention == "ema":
            m_upd = beta * state.momentum + (1.0 - beta) * g_flat
        else:  # pytorch
            m_upd = beta * state.momentum + g_flat
        m = jnp.where(state.step == 0, g_flat, m_upd)

        # message-level attack on the stacked momenta. k_attack is dedicated:
        # sharing the aggregator's key would correlate attacker randomness
        # with the defense's resampling permutation (ast-prng-reuse).
        sent, attack_state = self.attack(m, self.byz_mask, state.attack_state,
                                         key=k_attack)

        # mixing + robust aggregation
        if self.telemetry:
            agg, agg_stats = self.aggregator.aggregate_with_stats(sent, key=k_agg)
        else:
            agg = self.aggregator(sent, key=k_agg)
            agg_stats = {}

        # server update
        new_params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) - self.lr * u).astype(p.dtype),
            state.params,
            unflatten_like(agg, state.params),
        )

        metrics = {
            "grad_norm_mean": jnp.mean(jnp.linalg.norm(g_flat, axis=1)),
            "agg_norm": jnp.linalg.norm(agg),
            "zeta_sq": jnp.mean(
                jnp.sum(
                    jnp.square(
                        g_flat[self.n_byzantine:]
                        - jnp.mean(g_flat[self.n_byzantine:], axis=0, keepdims=True)
                    ),
                    axis=1,
                )
            ),
        }
        if self.telemetry:
            tmtree = dict(agg_stats)
            tmtree["byz_mask"] = self.byz_mask
            tmtree["grad_norm_mean"] = metrics["grad_norm_mean"]
            tmtree["agg_norm"] = metrics["agg_norm"]
            tmtree["zeta_sq"] = metrics["zeta_sq"]
            metrics["telemetry"] = tmtree
        return (
            SimState(new_params, m, attack_state, state.step + 1),
            metrics,
        )

    # ---------------------------------------------------------------- run
    def run(
        self,
        params0,
        data_x,
        data_y,
        n_steps: int,
        key,
        eval_fn: Optional[Callable] = None,
        eval_every: int = 50,
    ) -> Tuple[SimState, Dict[str, list]]:
        """Run ``n_steps``. With ``telemetry=True`` the history additionally
        carries ``history["telemetry"]``: each metric stacked across steps
        into one numpy array (leading step axis). Device metrics stay jax
        arrays during the loop — conversion happens once at the end, so
        async dispatch is never blocked mid-run."""
        import numpy as np

        state = self.init_state(params0)
        history: Dict[str, Any] = {"step": [], "eval": [], "zeta_sq": []}
        per_step: Dict[str, list] = {}
        for t in range(n_steps):
            key, sub = jax.random.split(key)
            state, metrics = self.step(state, data_x, data_y, sub)
            if self.telemetry:
                for name, v in metrics["telemetry"].items():
                    per_step.setdefault(name, []).append(v)
            if eval_fn is not None and ((t + 1) % eval_every == 0 or t == n_steps - 1):
                history["step"].append(t + 1)
                history["eval"].append(float(eval_fn(state.params)))
                history["zeta_sq"].append(float(metrics["zeta_sq"]))
        if self.telemetry:
            history["telemetry"] = {
                name: np.stack([np.asarray(v) for v in vs])
                for name, vs in per_step.items()
            }
        return state, history


def label_flip_targets(y: jnp.ndarray, n_classes: int = 10) -> jnp.ndarray:
    """The paper's label-flipping transform T(y) = 9 - y (data-level attack:
    apply to the Byzantine workers' dataset rows before training)."""
    return (n_classes - 1) - y
