"""Minimal dependency-free pytree checkpointing (npz + JSON manifest).

Layout:  <dir>/step_<N>/arrays.npz  +  <dir>/step_<N>/manifest.json

The manifest stores the flattened key paths and dtypes so restore rebuilds
the exact pytree structure. Works for params, optimizer state, and the
Byzantine trainer's momentum/attack state alike.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":  # ml_dtypes (bfloat16 &c) do not survive npz
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    path = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    flat = _flatten_with_paths(tree)
    np.savez(os.path.join(path, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and os.path.isdir(os.path.join(directory, d))
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, like_tree: Any, step: Optional[int] = None) -> Any:
    """Restore into the structure of ``like_tree`` (shapes must match)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like = _flatten_with_paths(like_tree)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
    paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(like_tree)[0]]
    keys = [
        "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        for path in paths
    ]
    new_leaves = [
        np.asarray(data[k]).astype(np.asarray(l).dtype) for k, l in zip(keys, leaves_like)
    ]
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
