"""The paper's contribution: mixing (bucketing/resampling) + agnostic robust
aggregation + worker momentum, plus the attacks it defends against."""

from repro.core.aggregators import (
    Aggregator,
    CenteredClip,
    CoordinateWiseMedian,
    Krum,
    Mean,
    RFA,
    TrimmedMean,
    get_aggregator,
)
from repro.core.aragg import DELTA_MAX, RobustAggregator, theorem1_s
from repro.core.attacks import Attack, get_attack
from repro.core.mixing import (
    Bucketing,
    FixedGrouping,
    Mixer,
    NoMix,
    Resampling,
    get_mixer,
)
from repro.core.momentum import cclip_radius, momentum_update

__all__ = [
    "Aggregator",
    "Mean",
    "Krum",
    "CoordinateWiseMedian",
    "TrimmedMean",
    "RFA",
    "CenteredClip",
    "get_aggregator",
    "RobustAggregator",
    "DELTA_MAX",
    "theorem1_s",
    "Attack",
    "get_attack",
    "Mixer",
    "NoMix",
    "Bucketing",
    "Resampling",
    "FixedGrouping",
    "get_mixer",
    "momentum_update",
    "cclip_radius",
]
