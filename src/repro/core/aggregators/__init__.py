"""Robust aggregation rules (the defenses studied by the paper)."""

from __future__ import annotations

from typing import Any, Dict

from repro.core.aggregators.base import Aggregator, Mean, pairwise_sq_dists_from_gram
from repro.core.aggregators.cclip import AdaptiveCenteredClip, CenteredClip
from repro.core.aggregators.krum import Krum
from repro.core.aggregators.median import CoordinateWiseMedian, TrimmedMean
from repro.core.aggregators.rfa import RFA

_REGISTRY: Dict[str, Any] = {
    "mean": Mean,
    "avg": Mean,
    "krum": Krum,
    "cm": CoordinateWiseMedian,
    "median": CoordinateWiseMedian,
    "rfa": RFA,
    "gm": RFA,
    "cclip": CenteredClip,
    "acclip": AdaptiveCenteredClip,
    "tm": TrimmedMean,
    "trimmed_mean": TrimmedMean,
}


def get_aggregator(name: str, **kwargs) -> Aggregator:
    """Build an aggregator by registry name (case-insensitive)."""
    key = name.lower()
    if key not in _REGISTRY:
        raise KeyError(f"unknown aggregator {name!r}; have {sorted(set(_REGISTRY))}")
    return _REGISTRY[key](**kwargs)


__all__ = [
    "Aggregator",
    "Mean",
    "Krum",
    "CoordinateWiseMedian",
    "TrimmedMean",
    "RFA",
    "CenteredClip",
    "AdaptiveCenteredClip",
    "get_aggregator",
    "pairwise_sq_dists_from_gram",
]
