"""Centered clipping (Karimireddy et al., 2021).

    CCLIP(x_1..x_n; v, tau) = v + (1/n) sum_i (x_i - v) * min(1, tau / ||x_i - v||)

iterated ``n_iters`` times, starting from an initial guess ``v0``. The paper
(Remark 3) notes CCLIP satisfies Definition A with delta_max = 0.1 even
without bucketing, but is *not agnostic*: tau must be supplied. We reproduce
the paper's rule tau = 10 / (1 - beta) at the call site.

Gram-space form: if ``v0`` is in span{x_i} (we use v0 = mean by default, or
caller-provided coefficients), every iterate stays in the span:

    v' = (1 - mean_i(lam_i)) v + (1/n) sum_i lam_i x_i,
    lam_i = min(1, tau / ||x_i - v||),

so CCLIP also reduces to coefficient-space iterations over the Gram matrix.
For a *warm-start* v from the previous step (out of span), the distributed
path appends v as an (n+1)-th pseudo-input to the Gram computation.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.aggregators.base import Aggregator


def _cclip_stats(lam_seq: jnp.ndarray, tau_seq: jnp.ndarray) -> dict:
    """Common telemetry dict from per-iteration clip weights and radii."""
    lam32 = lam_seq.astype(jnp.float32)
    return {
        "cclip_lam": lam32,                                     # [T, n]
        "cclip_clip_frac": jnp.mean(
            (lam32 < 1.0).astype(jnp.float32), axis=1),         # [T]
        "cclip_tau": jnp.asarray(tau_seq, jnp.float32),         # [T]
    }


class AdaptiveCenteredClip(Aggregator):
    """ACClip — beyond-paper: the paper's stated open problem (§6.4,
    Remark 3: "Ideally, one would want to adaptively and automatically set
    the clipping radius tau so that it works in all instances without any
    tuning. Designing such a clipping operator ... is left for future
    work.").

    Per iteration, the radius is set from the data itself:

        tau_t = tau_mult * median_i ||x_i - v_t||

    The median of distances is a robust scale estimate: with delta < 0.5 at
    least half the inputs are good, so the median distance is bounded by
    the good spread rho regardless of what the Byzantine inputs do —
    making the operator *agnostic* to rho (Definition A's requirement)
    while keeping CCLIP's contraction behaviour. With tau_mult >= 1 and no
    Byzantine inputs, at least half the workers are unclipped and the fixed
    point stays within O(rho) of the mean; Byzantine inputs further than
    tau are shrunk exactly as in fixed-radius CCLIP.

    Validated empirically in tests/test_aggregators.py (scale invariance:
    ACClip(c * xs) == c * ACClip(xs) exactly — fixed-tau CCLIP fails this)
    and benchmarks (fig2-style grid, gradient-scale sweep).
    """

    name = "acclip"

    def __init__(self, tau_mult: float = 1.0, n_iters: int = 5, eps: float = 1e-12):
        self.tau_mult = float(tau_mult)
        self.n_iters = int(n_iters)
        self.eps = float(eps)

    def aggregate(self, xs: jnp.ndarray, key: Optional[object] = None) -> jnp.ndarray:
        v = jnp.mean(xs, axis=0)

        def body(v, _):
            diff = xs - v[None, :]
            norms = jnp.sqrt(
                jnp.sum(jnp.square(diff.astype(jnp.float32)), axis=1) + self.eps
            )
            tau = self.tau_mult * jnp.median(norms)
            lam = jnp.minimum(1.0, tau / norms).astype(xs.dtype)
            return v + jnp.mean(lam[:, None] * diff, axis=0), None

        v, _ = jax.lax.scan(body, v, None, length=self.n_iters)
        return v

    def aggregate_and_stats(self, xs, key=None):
        v = jnp.mean(xs, axis=0)

        def body(v, _):
            diff = xs - v[None, :]
            norms = jnp.sqrt(
                jnp.sum(jnp.square(diff.astype(jnp.float32)), axis=1) + self.eps
            )
            tau = self.tau_mult * jnp.median(norms)
            lam = jnp.minimum(1.0, tau / norms).astype(xs.dtype)
            return v + jnp.mean(lam[:, None] * diff, axis=0), (lam, tau)

        v, (lam_seq, tau_seq) = jax.lax.scan(body, v, None, length=self.n_iters)
        return v, _cclip_stats(lam_seq, tau_seq)

    def coeffs(self, gram: jnp.ndarray, key: Optional[object] = None) -> jnp.ndarray:
        n = gram.shape[0]
        gram = gram.astype(jnp.float32)
        c0 = jnp.full((n,), 1.0 / n, dtype=jnp.float32)

        def resid_sq_norms(c):
            gc = gram @ c
            quad = c @ gc
            return jnp.maximum(quad - 2.0 * gc + jnp.diagonal(gram), 0.0)

        def body(c, _):
            norms = jnp.sqrt(resid_sq_norms(c) + self.eps)
            tau = self.tau_mult * jnp.median(norms)
            lam = jnp.minimum(1.0, tau / norms)
            return c * (1.0 - jnp.mean(lam)) + lam / n, None

        c, _ = jax.lax.scan(body, c0, None, length=self.n_iters)
        return c

    def coeffs_and_stats(self, gram, key=None):
        n = gram.shape[0]
        gram = gram.astype(jnp.float32)
        c0 = jnp.full((n,), 1.0 / n, dtype=jnp.float32)

        def resid_sq_norms(c):
            gc = gram @ c
            quad = c @ gc
            return jnp.maximum(quad - 2.0 * gc + jnp.diagonal(gram), 0.0)

        def body(c, _):
            norms = jnp.sqrt(resid_sq_norms(c) + self.eps)
            tau = self.tau_mult * jnp.median(norms)
            lam = jnp.minimum(1.0, tau / norms)
            return c * (1.0 - jnp.mean(lam)) + lam / n, (lam, tau)

        c, (lam_seq, tau_seq) = jax.lax.scan(body, c0, None, length=self.n_iters)
        return c, _cclip_stats(lam_seq, tau_seq)


class CenteredClip(Aggregator):
    name = "cclip"

    def __init__(self, tau: float = 10.0, n_iters: int = 3, eps: float = 1e-12):
        self.tau = float(tau)
        self.n_iters = int(n_iters)
        self.eps = float(eps)

    # ------------------------------------------------------------- stacked
    def aggregate(self, xs: jnp.ndarray, key: Optional[object] = None) -> jnp.ndarray:
        v = jnp.mean(xs, axis=0)

        def body(v, _):
            diff = xs - v[None, :]
            norms = jnp.sqrt(jnp.sum(jnp.square(diff.astype(jnp.float32)), axis=1) + self.eps)
            lam = jnp.minimum(1.0, self.tau / norms).astype(xs.dtype)
            v_new = v + jnp.mean(lam[:, None] * diff, axis=0)
            return v_new, None

        v, _ = jax.lax.scan(body, v, None, length=self.n_iters)
        return v

    def aggregate_and_stats(self, xs, key=None):
        v = jnp.mean(xs, axis=0)
        tau = jnp.float32(self.tau)

        def body(v, _):
            diff = xs - v[None, :]
            norms = jnp.sqrt(jnp.sum(jnp.square(diff.astype(jnp.float32)), axis=1) + self.eps)
            lam = jnp.minimum(1.0, self.tau / norms).astype(xs.dtype)
            v_new = v + jnp.mean(lam[:, None] * diff, axis=0)
            return v_new, (lam, tau)

        v, (lam_seq, tau_seq) = jax.lax.scan(body, v, None, length=self.n_iters)
        return v, _cclip_stats(lam_seq, tau_seq)

    # ---------------------------------------------------------- gram space
    def coeffs(self, gram: jnp.ndarray, key: Optional[object] = None) -> jnp.ndarray:
        n = gram.shape[0]
        gram = gram.astype(jnp.float32)
        c0 = jnp.full((n,), 1.0 / n, dtype=jnp.float32)  # v0 = mean

        def resid_sq_norms(c):
            gc = gram @ c
            quad = c @ gc
            return jnp.maximum(quad - 2.0 * gc + jnp.diagonal(gram), 0.0)

        def body(c, _):
            norms = jnp.sqrt(resid_sq_norms(c) + self.eps)
            lam = jnp.minimum(1.0, self.tau / norms)
            # v' = v + (1/n) sum_i lam_i (x_i - v)
            c_new = c * (1.0 - jnp.mean(lam)) + lam / n
            return c_new, None

        c, _ = jax.lax.scan(body, c0, None, length=self.n_iters)
        return c

    def coeffs_and_stats(self, gram, key=None):
        n = gram.shape[0]
        gram = gram.astype(jnp.float32)
        c0 = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
        tau = jnp.float32(self.tau)

        def resid_sq_norms(c):
            gc = gram @ c
            quad = c @ gc
            return jnp.maximum(quad - 2.0 * gc + jnp.diagonal(gram), 0.0)

        def body(c, _):
            norms = jnp.sqrt(resid_sq_norms(c) + self.eps)
            lam = jnp.minimum(1.0, self.tau / norms)
            c_new = c * (1.0 - jnp.mean(lam)) + lam / n
            return c_new, (lam, tau)

        c, (lam_seq, tau_seq) = jax.lax.scan(body, c0, None, length=self.n_iters)
        return c, _cclip_stats(lam_seq, tau_seq)
