"""Krum and Multi-Krum (Blanchard et al., 2017).

``Krum`` selects the worker whose summed squared distance to its
``n - f - 2`` nearest neighbours is smallest. Multi-Krum averages the ``m``
best-scoring workers. Both are one-hot / sparse in the workers, so the
Gram-space form is exact.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.aggregators.base import Aggregator, pairwise_sq_dists_from_gram


class Krum(Aggregator):
    name = "krum"

    def __init__(self, n_byzantine: int = 0, m: int = 1):
        """Args:
        n_byzantine: assumed number of Byzantine inputs ``f`` (score uses
            the ``n - f - 2`` closest neighbours, as in the paper).
        m: number of top-scoring workers to average (``m=1`` = classic Krum).
        """
        self.n_byzantine = int(n_byzantine)
        self.m = int(m)

    def scores(self, gram: jnp.ndarray) -> jnp.ndarray:
        n = gram.shape[0]
        dists = pairwise_sq_dists_from_gram(gram)
        # exclude self-distance by making it +inf, then take the
        # (n - f - 2) closest others for each row.
        big = jnp.finfo(jnp.float32).max
        dists = dists + jnp.eye(n, dtype=dists.dtype) * big
        k = max(1, min(n - 1, n - self.n_byzantine - 2))
        # only the k smallest distances matter: top_k on the negated matrix
        # beats a full row sort (k <= n-1 of n values, and lax.top_k avoids
        # XLA's slow variadic sort path on CPU).
        neg_topk, _ = jax.lax.top_k(-dists, k)
        return -jnp.sum(neg_topk, axis=1)

    def coeffs(self, gram, key: Optional[object] = None):
        n = gram.shape[0]
        s = self.scores(gram)
        if self.m <= 1:
            return jnp.zeros((n,), jnp.float32).at[jnp.argmin(s)].set(1.0)
        # multi-krum: average of the m best
        order = jnp.argsort(s)
        w = jnp.zeros((n,), jnp.float32)
        w = w.at[order[: self.m]].set(1.0 / self.m)
        return w

    def coeffs_and_stats(self, gram, key: Optional[object] = None):
        n = gram.shape[0]
        s = self.scores(gram)
        stats = {
            "krum_scores": s,
            "krum_selected": jnp.argmin(s).astype(jnp.int32),
        }
        if self.m <= 1:
            w = jnp.zeros((n,), jnp.float32).at[jnp.argmin(s)].set(1.0)
            return w, stats
        order = jnp.argsort(s)
        w = jnp.zeros((n,), jnp.float32)
        w = w.at[order[: self.m]].set(1.0 / self.m)
        return w, stats

    def selected_index(self, xs: jnp.ndarray) -> jnp.ndarray:
        """Index of the selected worker (used by the Figure-6 experiment)."""
        gram = xs.astype(jnp.float32) @ xs.astype(jnp.float32).T
        return jnp.argmin(self.scores(gram))
