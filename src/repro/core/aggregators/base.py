"""Robust aggregator abstraction.

Every aggregator supports two equivalent forms:

1. **Stacked form** — ``aggregate(xs)`` with ``xs: [n, d]`` returning ``[d]``.
   Used by the paper-scale simulation path (MNIST experiments) where the
   whole stacked gradient matrix fits in memory.

2. **Factorized (Gram-space) form** — for the distributed path where the
   ``[n_workers, n_params]`` matrix must never exist. Aggregators declare
   either:

   - ``coordinatewise = True`` (CM, trimmed mean): aggregation is exact when
     applied leaf-by-leaf via ``combine_leaf``; or
   - a ``coeffs(gram, key)`` method mapping the ``[n, n]`` fp32 Gram matrix
     ``G[i, j] = <x_i, x_j>`` to combination coefficients ``w: [n]`` such
     that the aggregate equals ``sum_i w_i x_i`` *exactly* (Krum: one-hot;
     RFA: Weiszfeld weights computed in coefficient space; CCLIP: clipped
     update run in coefficient space; mean: uniform).

   The Gram trick works because every iterate of these algorithms stays in
   ``span{x_1..x_n}``, and all required norms/distances are bilinear forms
   of G. Mixing (bucketing/resampling) is a linear operator ``M`` and
   composes as ``G_mixed = M G M^T`` with final worker weights ``M^T w``.
"""

from __future__ import annotations

import abc
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def pairwise_sq_dists_from_gram(gram: jnp.ndarray) -> jnp.ndarray:
    """``D[i,j] = ||x_i - x_j||^2`` from the Gram matrix."""
    diag = jnp.diagonal(gram)
    return diag[:, None] + diag[None, :] - 2.0 * gram


class Aggregator(abc.ABC):
    """Base class. Subclasses set ``name`` and implement one of the forms."""

    name: str = "base"
    #: True => exact leaf-local aggregation via combine_leaf (CM, TM).
    coordinatewise: bool = False

    # ---------------------------------------------------------------- stacked
    def aggregate(self, xs: jnp.ndarray, key: Optional[jax.Array] = None) -> jnp.ndarray:
        """Aggregate stacked worker vectors ``xs: [n, d] -> [d]``."""
        if self.coordinatewise:
            return self.combine_leaf(xs)
        gram = (xs.astype(jnp.float32) @ xs.astype(jnp.float32).T)
        w = self.coeffs(gram, key=key)
        return jnp.tensordot(w.astype(xs.dtype), xs, axes=1)

    def aggregate_and_stats(
        self, xs: jnp.ndarray, key: Optional[jax.Array] = None
    ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        """``aggregate`` plus the telemetry stats dict.

        The stats variants add scan outputs / post-hoc reductions but never
        touch the carry math, so the aggregate matches ``aggregate(xs, key)``
        up to XLA fusion-level rounding (~1 ulp — extra scan ys change how
        the body fuses). The telemetry-OFF path never calls this, so off
        stays bit-exact vs seed. Only called on telemetry-on paths."""
        from repro.telemetry import probes  # local: telemetry is optional

        if self.coordinatewise:
            out = self.combine_leaf(xs)
            return out, probes.coordinatewise_stats(self, xs, out)
        gram = (xs.astype(jnp.float32) @ xs.astype(jnp.float32).T)
        w, stats = self.coeffs_and_stats(gram, key=key)
        stats["bucket_dispersion"] = probes.bucket_dispersion_from_gram(gram)
        return jnp.tensordot(w.astype(xs.dtype), xs, axes=1), stats

    # ------------------------------------------------------------- factorized
    def coeffs(self, gram: jnp.ndarray, key: Optional[jax.Array] = None) -> jnp.ndarray:
        """Combination coefficients ``[n]`` from the Gram matrix ``[n, n]``."""
        raise NotImplementedError(
            f"{type(self).__name__} does not implement the Gram-space form"
        )

    def coeffs_and_stats(
        self, gram: jnp.ndarray, key: Optional[jax.Array] = None
    ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        """``coeffs`` plus the telemetry stats dict (same numerics contract
        as ``aggregate_and_stats``). Default: no stats."""
        return self.coeffs(gram, key=key), {}

    def combine_leaf(self, xs_leaf: jnp.ndarray) -> jnp.ndarray:
        """Exact leaf-local aggregation ``[n, ...] -> [...]`` (coordinatewise only)."""
        raise NotImplementedError(
            f"{type(self).__name__} is not coordinatewise"
        )

    # ----------------------------------------------------------------- extras
    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"{type(self).__name__}()"


class Mean(Aggregator):
    """Plain averaging — the non-robust baseline (``Avg`` in the paper)."""

    name = "mean"

    def coeffs(self, gram, key=None):
        n = gram.shape[0]
        return jnp.full((n,), 1.0 / n, dtype=jnp.float32)

    def aggregate(self, xs, key=None):
        return jnp.mean(xs, axis=0)
