"""Coordinate-wise median (Yin et al., 2018) and coordinate-wise trimmed mean.

Both are coordinatewise, hence *exactly* leaf-local: aggregating each pytree
leaf (or each shard of a leaf) independently gives the same result as on the
concatenated vector. This makes them trivially compatible with the
factorized distributed path.

Both run on the pruned Batcher selection network
(repro/kernels/selection_network.py) instead of ``jnp.sort``: only the
needed order statistics are materialized, as unrolled vectorized min/max —
value-equal to the sort (same input multiset -> same order statistics) and
~40x faster on the CPU backend, where XLA's variadic sort is the single
slowest op in the whole aggregator zoo (BENCH_agg_microbench.json).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.aggregators.base import Aggregator
from repro.kernels.selection_network import median_select, trimmed_mean_select


class CoordinateWiseMedian(Aggregator):
    name = "cm"
    coordinatewise = True

    def combine_leaf(self, xs_leaf: jnp.ndarray) -> jnp.ndarray:
        # median over the worker axis; for even n this is the midpoint of the
        # two central order statistics (jnp.median semantics), matching the
        # minimizer set of sum_i |v - x_i|.
        return median_select(xs_leaf.astype(jnp.float32)).astype(xs_leaf.dtype)


class TrimmedMean(Aggregator):
    """Coordinate-wise trimmed mean (``TM`` with ``b = f`` in the paper's table)."""

    name = "tm"
    coordinatewise = True

    def __init__(self, n_trim: int = 1):
        self.n_trim = int(n_trim)

    def combine_leaf(self, xs_leaf: jnp.ndarray) -> jnp.ndarray:
        n = xs_leaf.shape[0]
        b = min(self.n_trim, (n - 1) // 2)
        out = trimmed_mean_select(xs_leaf.astype(jnp.float32), b)
        return out.astype(xs_leaf.dtype)
