"""RFA — geometric median via the smoothed Weiszfeld algorithm
(Pillutla et al., 2019).

Weiszfeld iterates ``v <- sum_i w_i x_i / sum_i w_i`` with
``w_i = 1 / max(eps, ||v - x_i||)``. Every iterate lies in the convex hull
of the inputs, so with ``v = sum_i c_i x_i`` all residual norms are bilinear
forms of the Gram matrix:

    ||v - x_i||^2 = c^T G c - 2 (G c)_i + G_ii

which lets us run the whole algorithm in coefficient space (``coeffs``),
touching the actual d-dimensional vectors only once at the end. This is the
key to the factorized distributed path (see repro/distributed/robust_sync).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.aggregators.base import Aggregator


class RFA(Aggregator):
    name = "rfa"

    def __init__(self, n_iters: int = 8, eps: float = 1e-6):
        """Args:
        n_iters: Weiszfeld iterations ``T`` (paper default T=8).
        eps: smoothing constant nu of the smoothed Weiszfeld algorithm.
        """
        self.n_iters = int(n_iters)
        self.eps = float(eps)

    def coeffs(self, gram: jnp.ndarray, key: Optional[object] = None) -> jnp.ndarray:
        n = gram.shape[0]
        gram = gram.astype(jnp.float32)
        c0 = jnp.full((n,), 1.0 / n, dtype=jnp.float32)  # start from the mean

        def resid_sq_norms(c):
            gc = gram @ c
            quad = c @ gc
            return jnp.maximum(quad - 2.0 * gc + jnp.diagonal(gram), 0.0)

        def body(c, _):
            r = jnp.sqrt(resid_sq_norms(c) + self.eps**2)
            w = 1.0 / r
            c_new = w / jnp.sum(w)
            return c_new, None

        c, _ = jax.lax.scan(body, c0, None, length=self.n_iters)
        return c

    def coeffs_and_stats(self, gram, key=None):
        """``coeffs`` + per-iteration residual norms. Identical carry math —
        only the scan's ys output is added (fusion may shift the result by
        ~1 ulp; the telemetry-off path still calls plain ``coeffs``)."""
        n = gram.shape[0]
        gram = gram.astype(jnp.float32)
        c0 = jnp.full((n,), 1.0 / n, dtype=jnp.float32)

        def resid_sq_norms(c):
            gc = gram @ c
            quad = c @ gc
            return jnp.maximum(quad - 2.0 * gc + jnp.diagonal(gram), 0.0)

        def body(c, _):
            r = jnp.sqrt(resid_sq_norms(c) + self.eps**2)
            w = 1.0 / r
            c_new = w / jnp.sum(w)
            return c_new, r

        c, r_seq = jax.lax.scan(body, c0, None, length=self.n_iters)
        stats = {
            "rfa_resid_norms": r_seq,                      # [T, n]
            "rfa_residual": jnp.sum(r_seq, axis=1),        # [T] Weiszfeld objective
            "rfa_iters": self.n_iters,
        }
        return c, stats
