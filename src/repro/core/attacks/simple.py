"""Bit-flipping, IPM and ALIE attacks.

- **BF** (bit flipping): Byzantine rows send the negation of what they would
  have sent (sign-bit flip, modeling e.g. hardware faults).
- **IPM** (inner-product manipulation, Xie et al. 2020): Byzantine rows send
  ``-(eps/|G|) sum_{i in G} x_i`` — a small consistent bias whose inner
  product with the true mean is negative. Paper uses eps = 0.1.
- **ALIE** ("a little is enough", Baruch et al. 2019): Byzantine rows send
  ``mu_G - z * sigma_G`` with z chosen from the normal CDF so the perturbed
  value stays inside the plausible range of good updates.

Label-flipping is a *data* attack and lives in repro/core/byzantine.py
(it corrupts the Byzantine workers' datasets, not their messages).
"""

from __future__ import annotations

import math

import jax.numpy as jnp

from repro.core.attacks.base import Attack, good_mean, good_std


class BitFlipping(Attack):
    name = "bitflip"

    def __call__(self, xs, byz_mask, state=None, key=None):
        return jnp.where(byz_mask[:, None], -xs, xs), state


class IPM(Attack):
    name = "ipm"

    def __init__(self, eps: float = 0.1):
        self.eps = float(eps)

    def __call__(self, xs, byz_mask, state=None, key=None):
        mal = (-self.eps) * good_mean(xs, byz_mask)
        return jnp.where(byz_mask[:, None], mal[None, :].astype(xs.dtype), xs), state


def alie_z(n: int, f: int) -> float:
    """z = max z s.t. phi(z) < (n - f - s)/(n - f), s = floor(n/2 + 1) - f.

    (Baruch et al. 2019; the paper reports z ~= 0.25 for n=25, f=5.)
    """
    s = math.floor(n / 2 + 1) - f
    p = (n - f - s) / max(n - f, 1)
    p = min(max(p, 1e-6), 1 - 1e-6)
    # inverse normal CDF via erfinv
    return math.sqrt(2.0) * _erfinv(2 * p - 1)


def _erfinv(x: float) -> float:
    # Winitzki's approximation — plenty for picking the attack strength.
    a = 0.147
    ln1 = math.log(1 - x * x)
    term = 2 / (math.pi * a) + ln1 / 2
    return math.copysign(math.sqrt(math.sqrt(term**2 - ln1 / a) - term), x)


class ALIE(Attack):
    name = "alie"

    def __init__(self, z: float | None = None, n: int | None = None, f: int | None = None):
        if z is None:
            if n is None or f is None:
                raise ValueError("ALIE needs either z or (n, f)")
            z = alie_z(n, f)
        self.z = float(z)

    def __call__(self, xs, byz_mask, state=None, key=None):
        mu = good_mean(xs, byz_mask)
        sd = good_std(xs, byz_mask)
        mal = (mu - self.z * sd).astype(xs.dtype)
        return jnp.where(byz_mask[:, None], mal[None, :], xs), state
