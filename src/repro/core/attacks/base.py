"""Attack abstraction.

An attack transforms the stacked matrix of *would-be* worker updates
``[n, d]`` (rows ``byz_mask`` True are under adversary control) into the
matrix actually sent to the server. Attacks may carry state (e.g. mimic's
streaming top-eigenvector) threaded through ``update_state``.

Byzantine workers are omniscient per the threat model: they see all good
updates and may collude.
"""

from __future__ import annotations

import abc
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


class Attack(abc.ABC):
    name: str = "attack"

    def init_state(self, n: int, d: int) -> Any:
        return None

    @abc.abstractmethod
    def __call__(
        self,
        xs: jnp.ndarray,
        byz_mask: jnp.ndarray,
        state: Any = None,
        key: Optional[jax.Array] = None,
    ) -> Tuple[jnp.ndarray, Any]:
        """Return (attacked xs, new state)."""

    def __repr__(self) -> str:  # pragma: no cover
        return f"{type(self).__name__}()"


class NoAttack(Attack):
    name = "none"

    def __call__(self, xs, byz_mask, state=None, key=None):
        return xs, state


def good_mean(xs: jnp.ndarray, byz_mask: jnp.ndarray) -> jnp.ndarray:
    w = (~byz_mask).astype(jnp.float32)
    return (w @ xs.astype(jnp.float32)) / jnp.maximum(jnp.sum(w), 1.0)


def good_std(xs: jnp.ndarray, byz_mask: jnp.ndarray) -> jnp.ndarray:
    mu = good_mean(xs, byz_mask)
    w = (~byz_mask).astype(jnp.float32)[:, None]
    var = jnp.sum(w * jnp.square(xs.astype(jnp.float32) - mu), axis=0) / jnp.maximum(
        jnp.sum(w), 1.0
    )
    return jnp.sqrt(var)
