"""The mimic attack (paper §3.2, App. B).

All Byzantine workers copy the update of one *good* worker ``i_star``,
over-emphasizing it and under-representing the others. Undetectable by
construction (the copied vector is a legitimate update).

``i_star`` is chosen during a warmup phase ``I_0`` to maximize
``|sum_t z^T x_i^t|`` along the direction ``z`` of maximum across-worker
variance; ``z`` is maintained online with Oja's rule (App. B):

    mu^{t+1} = t/(t+1) mu^t + 1/(t+1) mean_G(x^t)
    z^{t+1} ~ t/(t+1) z^t + 1/(t+1) sum_G (x_i - mu)(x_i - mu)^T z^t
    i_star^t = argmax_i | z^T x_i^t |   (cumulative score over warmup)
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.attacks.base import Attack, good_mean


class MimicState(NamedTuple):
    t: jnp.ndarray          # step counter (scalar int32)
    mu: jnp.ndarray         # running mean of good updates [d]
    z: jnp.ndarray          # Oja top-eigenvector estimate [d]
    score: jnp.ndarray      # cumulative |z . x_i| per worker [n]
    i_star: jnp.ndarray     # currently mimicked worker (scalar int32)


class Mimic(Attack):
    name = "mimic"

    def __init__(self, warmup_steps: int = 100):
        self.warmup_steps = int(warmup_steps)

    def init_state(self, n: int, d: int) -> MimicState:
        return MimicState(
            t=jnp.zeros((), jnp.int32),
            mu=jnp.zeros((d,), jnp.float32),
            z=jnp.ones((d,), jnp.float32) / jnp.sqrt(d),
            score=jnp.zeros((n,), jnp.float32),
            i_star=jnp.zeros((), jnp.int32),
        )

    def __call__(self, xs, byz_mask, state: Optional[MimicState] = None, key=None):
        if state is None:
            state = self.init_state(xs.shape[0], xs.shape[1])
        x32 = xs.astype(jnp.float32)
        good = (~byz_mask).astype(jnp.float32)
        t = state.t.astype(jnp.float32)

        # --- online mean and Oja top-eigenvector update over good updates
        mu = (t * state.mu + good_mean(xs, byz_mask)) / (t + 1.0)
        centered = (x32 - mu[None, :]) * good[:, None]
        cov_z = centered.T @ (centered @ state.z)  # sum_G (x-mu)(x-mu)^T z
        z = (t * state.z + cov_z) / (t + 1.0)
        z = z / jnp.maximum(jnp.linalg.norm(z), 1e-12)

        # --- cumulative projection scores; Byzantine rows excluded
        proj = jnp.abs(x32 @ z) * good
        score = state.score + proj

        in_warmup = state.t < self.warmup_steps
        i_star = jnp.where(in_warmup, jnp.argmax(score), state.i_star).astype(jnp.int32)

        new_state = MimicState(state.t + 1, mu, z, score, i_star)
        mal = xs[i_star]
        return jnp.where(byz_mask[:, None], mal[None, :], xs), new_state


class MimicFixed(Attack):
    """Mimic a fixed worker index (the paper's §3.2 intuition example)."""

    name = "mimic_fixed"

    def __init__(self, i_star: int = 0):
        self.i_star = int(i_star)

    def __call__(self, xs, byz_mask, state=None, key=None):
        mal = xs[self.i_star]
        return jnp.where(byz_mask[:, None], mal[None, :], xs), state
