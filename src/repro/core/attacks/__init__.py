"""Byzantine attacks studied by the paper (§3.2, §6.2)."""

from __future__ import annotations

from typing import Any, Dict

from repro.core.attacks.base import Attack, NoAttack, good_mean, good_std
from repro.core.attacks.mimic import Mimic, MimicFixed, MimicState
from repro.core.attacks.simple import ALIE, IPM, BitFlipping, alie_z

_REGISTRY: Dict[str, Any] = {
    "none": NoAttack,
    "bitflip": BitFlipping,
    "bf": BitFlipping,
    "ipm": IPM,
    "alie": ALIE,
    "mimic": Mimic,
    "mimic_fixed": MimicFixed,
}


def get_attack(name: str, **kwargs) -> Attack:
    key = (name or "none").lower()
    if key not in _REGISTRY:
        raise KeyError(f"unknown attack {name!r}; have {sorted(set(_REGISTRY))}")
    return _REGISTRY[key](**kwargs)


__all__ = [
    "Attack",
    "NoAttack",
    "BitFlipping",
    "IPM",
    "ALIE",
    "Mimic",
    "MimicFixed",
    "MimicState",
    "alie_z",
    "get_attack",
    "good_mean",
    "good_std",
]
