"""Worker momentum (Algorithm 2) and server momentum (Remark 7).

Algorithm 2 (paper-faithful EMA convention):

    m_i^t = beta * m_i^{t-1} + (1 - beta) * g_i(x^{t-1})     (workers)
    x^t   = x^{t-1} - eta * ARAGG(m_1^t .. m_n^t)            (server)

The PyTorch convention ``m <- beta m + g`` (used by the paper's experiments,
App. A.2.1, motivating the tau = 10/(1-beta) clipping-radius scaling) is
also supported via ``convention="pytorch"``.

Server momentum (Remark 7, cross-device FL / history-less workers): workers
send raw gradients, the server robust-aggregates then applies momentum to
the *aggregate*. Its state is O(model) not O(n_workers * model), which is
what the giant-model configs use (see DESIGN.md §5).
"""

from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

Convention = Literal["ema", "pytorch"]


def momentum_update(m, g, beta: float, convention: Convention = "ema"):
    """One momentum step on a pytree (or stacked array) of gradients."""
    if convention == "ema":
        return jax.tree_util.tree_map(
            lambda mi, gi: beta * mi + (1.0 - beta) * gi, m, g
        )
    if convention == "pytorch":
        return jax.tree_util.tree_map(lambda mi, gi: beta * mi + gi, m, g)
    raise ValueError(f"unknown momentum convention {convention!r}")


def init_worker_momentum(g0):
    """Paper initialization: m^1 = g(x^0) (i.e. alpha=0 at t=1)."""
    return g0


def cclip_radius(beta: float, base_tau: float = 10.0, scaling: str = "linear") -> float:
    """The paper's clipping-radius rule for CCLIP (App. A.2.1).

    linear: tau = base / (1 - beta)   (recommended)
    sqrt:   tau = base / sqrt(1 - beta)
    none:   tau = base
    """
    if scaling == "linear":
        return base_tau / (1.0 - beta) if beta < 1.0 else float("inf")
    if scaling == "sqrt":
        return base_tau / (1.0 - beta) ** 0.5 if beta < 1.0 else float("inf")
    if scaling == "none":
        return base_tau
    raise ValueError(f"unknown scaling {scaling!r}")
