"""(delta_max, c)-Agnostic Robust Aggregator (Definition A + Theorem I).

``RobustAggregator`` composes a ``Mixer`` (bucketing / resampling) with a
base ``Aggregator``. Theorem I instantiates ``s = delta_max / delta`` so
that after mixing the Byzantine fraction is pushed up to the base rule's
breakdown point while the pairwise variance drops by ``s``:

    Krum  o Mix : delta_max < 1/4,  c = 1/(nu (1/4 - nu))
    RFA   o Mix : delta_max < 1/2,  c = 1/(nu (1/2 - nu))
    CM    o Mix : delta_max < 1/2,  c = d/(nu (1/2 - nu))
    CCLIP       : delta_max = 0.1 even unmixed (Remark 3), not agnostic.

The aggregate is agnostic to rho^2 (only delta is an input), which is what
lets it adapt as worker gradients concentrate during training (crucial for
the overparameterized Theorem IV regime).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.aggregators import Aggregator, get_aggregator
from repro.core.mixing import Bucketing, Mixer, NoMix, get_mixer

#: Theorem-I breakdown points per base rule.
DELTA_MAX = {
    "krum": 0.25,
    "rfa": 0.5,
    "gm": 0.5,
    "cm": 0.5,
    "median": 0.5,
    "tm": 0.5,
    "trimmed_mean": 0.5,
    "cclip": 0.1,
    "mean": 0.0,
    "avg": 0.0,
}


def theorem1_s(delta: float, delta_max: float, n: int) -> int:
    """``s = delta_max / delta`` capped so mixed inputs keep a good majority."""
    if delta <= 0:
        return 1
    s = int(math.floor(delta_max / delta))
    return max(1, min(s, n))


class RobustAggregator:
    """Mixer o Aggregator composition with the Theorem-I contract.

    Can be called on stacked vectors (simulation path) or queried for
    ``(mixing matrix, aggregator)`` by the factorized distributed path.
    """

    def __init__(self, base: Aggregator, mixer: Optional[Mixer] = None):
        self.base = base
        self.mixer = mixer if mixer is not None else NoMix()

    # ----------------------------------------------------------- construction
    @classmethod
    def from_spec(
        cls,
        agg: str,
        mixing: str = "bucketing",
        s: Optional[int] = None,
        delta: Optional[float] = None,
        n_workers: Optional[int] = None,
        **agg_kwargs,
    ) -> "RobustAggregator":
        """Build from string spec. If ``s`` is None it is derived from
        Theorem I as ``floor(delta_max / delta)`` (requires ``delta``)."""
        base = get_aggregator(agg, **agg_kwargs)
        if s is None:
            if delta is None:
                s = 2  # the paper's recommended mild default
            else:
                s = theorem1_s(delta, DELTA_MAX.get(agg.lower(), 0.25), n_workers or 2**30)
        mixer = get_mixer(mixing, s=s)
        return cls(base, mixer)

    # ----------------------------------------------------------------- stacked
    def __call__(self, xs: jnp.ndarray, key: Optional[jax.Array] = None) -> jnp.ndarray:
        """Aggregate stacked worker vectors ``[n, d] -> [d]``."""
        mix_key, agg_key = (None, None) if key is None else tuple(jax.random.split(key))
        ys = self.mixer.apply(mix_key, xs)
        return self.base.aggregate(ys, key=agg_key)

    def aggregate_with_stats(self, xs, key: Optional[jax.Array] = None):
        """``__call__`` plus the base rule's telemetry stats dict.

        Same math as ``__call__`` — only extra scan outputs are added inside
        ``aggregate_and_stats`` (agreement to ~1 ulp; the telemetry-off path
        is ``__call__`` itself and stays bit-exact vs seed). Stats are keyed
        per *mixed row* (post-bucketing); with ``mixing="none"`` they
        attribute directly to workers."""
        mix_key, agg_key = (None, None) if key is None else tuple(jax.random.split(key))
        ys = self.mixer.apply(mix_key, xs)
        return self.base.aggregate_and_stats(ys, key=agg_key)

    # ------------------------------------------------------------- gram space
    def worker_weights_from_gram(
        self, gram: jnp.ndarray, key: Optional[jax.Array] = None
    ) -> jnp.ndarray:
        """Exact per-worker combination weights ``[n]`` for non-coordinatewise
        base rules: ``w = M^T coeffs(M G M^T)``."""
        if self.base.coordinatewise:
            raise ValueError("coordinatewise base rules do not use Gram weights")
        n = gram.shape[0]
        mix_key, agg_key = (None, None) if key is None else tuple(jax.random.split(key))
        m = self.mixer.matrix(mix_key, n)
        gram_y = m @ gram.astype(jnp.float32) @ m.T
        c = self.base.coeffs(gram_y, key=agg_key)
        return m.T @ c

    def worker_weights_and_stats_from_gram(
        self, gram: jnp.ndarray, key: Optional[jax.Array] = None
    ):
        """``worker_weights_from_gram`` plus telemetry stats (weights agree
        to ~1 ulp — see ``aggregate_with_stats``). Adds per-bucket dispersion
        from the mixed Gram matrix and the final per-worker weights
        ``M^T c``."""
        from repro.telemetry import probes  # local: telemetry is optional

        if self.base.coordinatewise:
            raise ValueError("coordinatewise base rules do not use Gram weights")
        n = gram.shape[0]
        mix_key, agg_key = (None, None) if key is None else tuple(jax.random.split(key))
        m = self.mixer.matrix(mix_key, n)
        gram_y = m @ gram.astype(jnp.float32) @ m.T
        c, stats = self.base.coeffs_and_stats(gram_y, key=agg_key)
        w = m.T @ c
        stats["bucket_dispersion"] = probes.bucket_dispersion_from_gram(gram_y)
        stats["worker_weights"] = w
        return w, stats

    def mixing_matrix(self, key: Optional[jax.Array], n: int) -> jnp.ndarray:
        mix_key = None if key is None else jax.random.split(key)[0]
        return self.mixer.matrix(mix_key, n)

    def __repr__(self) -> str:  # pragma: no cover
        return f"RobustAggregator({self.base!r}, {self.mixer!r})"
