"""Executable versions of the paper's theoretical objects.

- Lemma 1: mixing reduces pairwise variance by ``s`` while expanding the
  Byzantine fraction to ``s * delta`` — certified empirically by
  ``mixed_pairwise_variance``.
- Theorem III: the two-instance lower-bound construction
  (``LowerBoundInstance``) showing no algorithm can beat ``Omega(delta zeta^2)``.
- Heterogeneity / variance estimators (zeta^2, rho^2) used by benchmarks.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp


# ----------------------------------------------------------- variance metrics
def pairwise_variance(xs: jnp.ndarray) -> jnp.ndarray:
    """Empirical ``rho^2 = E_{i != j} ||x_i - x_j||^2`` over stacked vectors."""
    n = xs.shape[0]
    xs = xs.astype(jnp.float32)
    gram = xs @ xs.T
    d2 = jnp.diagonal(gram)[:, None] + jnp.diagonal(gram)[None, :] - 2 * gram
    off = jnp.sum(d2) - jnp.sum(jnp.diagonal(d2))
    return off / (n * (n - 1))


def heterogeneity_zeta_sq(grads: jnp.ndarray) -> jnp.ndarray:
    """``zeta^2 = E_i ||g_i - gbar||^2`` over stacked worker gradients."""
    g = grads.astype(jnp.float32)
    gbar = jnp.mean(g, axis=0, keepdims=True)
    return jnp.mean(jnp.sum(jnp.square(g - gbar), axis=1))


# --------------------------------------------------- Theorem III lower bound
@dataclasses.dataclass
class LowerBoundInstance:
    """The Theorem-III construction: two indistinguishable worker-function
    sets whose true optima differ, forcing error >= delta*zeta^2/(4 mu).

    World 1: all n workers good; delta*n of them have f_i = mu/2 x^2 - zeta
             delta^{-1/2} x, the rest f_i = mu/2 x^2.  Optimum G/mu.
    World 2: the first delta*n workers are Byzantine (sending exactly the
             same functions); good objective is mu/2 x^2. Optimum 0.
    """

    n: int = 10
    delta: float = 0.2
    zeta: float = 1.0
    mu: float = 1.0

    @property
    def n_byz(self) -> int:
        return int(self.delta * self.n)

    @property
    def G(self) -> float:
        return self.zeta * self.delta**0.5

    def worker_grad(self, i: int, x: jnp.ndarray) -> jnp.ndarray:
        """Gradient reported by worker i — IDENTICAL in both worlds."""
        if i < self.n_byz:
            return self.mu * x - self.zeta * self.delta ** (-0.5)
        return self.mu * x

    def optimum(self, world: int) -> float:
        return self.G / self.mu if world == 1 else 0.0

    def objective(self, world: int, x: jnp.ndarray) -> jnp.ndarray:
        if world == 1:
            return 0.5 * self.mu * x**2 - self.G * x
        return 0.5 * self.mu * x**2

    def suboptimality_floor(self) -> float:
        """The Omega(delta zeta^2 / mu) bound: max over worlds of f - f*."""
        return self.delta * self.zeta**2 / (4.0 * self.mu)

    def best_achievable_max_error(self) -> Tuple[float, float]:
        """The minimax-optimal output x = G/(2 mu) and its worst-case error."""
        x = self.G / (2 * self.mu)
        errs = tuple(
            float(self.objective(w, jnp.asarray(x)) - self.objective(w, jnp.asarray(self.optimum(w))))
            for w in (1, 2)
        )
        return x, max(errs)


# ------------------------------------------------ overparameterization (Thm IV)
def overparam_bound_ok(c: float, delta: float, B_sq: float) -> bool:
    """Theorem IV requires B^2 < 1/(3 c delta)."""
    if delta == 0:
        return True
    return B_sq < 1.0 / (3.0 * c * delta)
