"""Gradient mixing — the paper's core contribution (Algorithm 1).

Two variants by the same authors:

- **Resampling** (preprint, Algorithm 1): replicate each of the ``n`` inputs
  ``s`` times, randomly permute the ``s*n`` copies, average consecutive
  groups of ``s``. Output: ``n`` mixed vectors; each original input is used
  at most ``s`` times (s-resampling *without* replacement).
- **Bucketing** (ICLR camera-ready; preprint App. A.2.4): randomly permute
  the ``n`` inputs, split into ``ceil(n/s)`` buckets, average each bucket.
  Output: ``ceil(n/s)`` mixed vectors. Same Lemma-1 guarantee, but it also
  *shrinks* the aggregator's input set, reducing downstream cost.

Both are *linear* operators: ``y = M x`` with a row-stochastic ``[m, n]``
matrix whose entries are in ``{0, k/s}``. We exploit linearity everywhere:

- stacked path: ``ys = M @ xs``;
- Gram path:    ``G_y = M G_x M^T`` and final worker weights ``M^T w``;
- collective path: bucketing with contiguous buckets of the (already
  permuted) worker axis is a *hierarchical partial all-reduce* on the mesh.

``FixedGrouping`` (Chen et al., 2017 style, paper App. A.2.6) is bucketing
with the identity permutation, kept as a baseline.
"""

from __future__ import annotations

import abc
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@functools.lru_cache(maxsize=None)
def _bucketing_base(n: int, s: int) -> np.ndarray:
    """Identity-permutation bucketing matrix ``[ceil(n/s), n]`` (fp32): slot
    ``j`` feeds bucket ``j // s`` with weight ``1/|bucket|``. Static in
    ``(n, s)`` — the per-round work is only the column permutation. Cached
    as NUMPY: a jnp array built inside a jit trace is a tracer, and caching
    one leaks it across traces."""
    m = math.ceil(n / s)
    bucket_of = np.arange(n) // s
    sizes = np.bincount(bucket_of, minlength=m).astype(np.float32)
    base = np.zeros((m, n), np.float32)
    base[bucket_of, np.arange(n)] = 1.0
    base /= sizes[:, None]
    return base


@functools.lru_cache(maxsize=None)
def _resampling_src(n: int, s: int) -> np.ndarray:
    """Replica->input map of the ``s*n`` slots (== the slot->group map):
    slot ``k`` holds a replica of input ``k // s``. Static in ``(n, s)``;
    numpy-cached for the same trace-safety reason as ``_bucketing_base``."""
    return np.arange(s * n) // s


class Mixer(abc.ABC):
    """Builds the mixing matrix ``M: [m, n]`` for a given round."""

    name: str = "mixer"
    #: mixing factor s (1 = no-op shuffle)
    s: int = 1

    @abc.abstractmethod
    def n_out(self, n: int) -> int:
        ...

    @abc.abstractmethod
    def matrix(self, key: Optional[jax.Array], n: int) -> jnp.ndarray:
        """Return the row-stochastic mixing matrix ``[n_out, n]`` (fp32)."""

    # Convenience: stacked application.
    def apply(self, key: Optional[jax.Array], xs: jnp.ndarray) -> jnp.ndarray:
        m = self.matrix(key, xs.shape[0])
        return (m @ xs.astype(jnp.float32)).astype(xs.dtype)

    def __repr__(self) -> str:  # pragma: no cover
        return f"{type(self).__name__}(s={self.s})"


class NoMix(Mixer):
    """Identity (vanilla aggregation, the paper's 'without' columns)."""

    name = "none"
    s = 1

    def n_out(self, n: int) -> int:
        return n

    def matrix(self, key, n):
        return jnp.eye(n, dtype=jnp.float32)

    def apply(self, key, xs):
        return xs


class Bucketing(Mixer):
    """ICLR camera-ready bucketing: permute, split into ceil(n/s) buckets, average.

    If ``s`` does not divide ``n`` the last bucket is smaller; its row of M
    averages over the remaining inputs (still row-stochastic).
    """

    name = "bucketing"

    def __init__(self, s: int = 2):
        if s < 1:
            raise ValueError("s must be >= 1")
        self.s = int(s)

    def n_out(self, n: int) -> int:
        return math.ceil(n / self.s)

    def matrix(self, key, n):
        # bucket b holds permuted inputs [b*s, min((b+1)*s, n)); the static
        # scatter (bucket-of-slot + bucket sizes) is cached per (n, s) and
        # only the column permutation is per-round work.
        base = jnp.asarray(_bucketing_base(n, self.s))
        if key is None:
            return base
        perm = jax.random.permutation(key, n)
        return jnp.zeros_like(base).at[:, perm].set(base)


class FixedGrouping(Bucketing):
    """Bucketing without the per-round random permutation (Chen et al. 2017)."""

    name = "fixed_grouping"

    def matrix(self, key, n):
        return super().matrix(None, n)


class Resampling(Mixer):
    """Preprint Algorithm 1: s-fold replication + permutation + group-average.

    Each input is replicated exactly ``s`` times; the ``s*n`` slots are
    permuted and consecutive groups of ``s`` are averaged, producing ``n``
    outputs. Each input influences at most ``s`` outputs (sampling without
    replacement), which is what bounds the Byzantine amplification in
    Lemma 1.
    """

    name = "resampling"

    def __init__(self, s: int = 2):
        if s < 1:
            raise ValueError("s must be >= 1")
        self.s = int(s)

    def n_out(self, n: int) -> int:
        return n

    def matrix(self, key, n):
        s = self.s
        total = s * n
        # replica k comes from input k // s; slot t feeds output group t // s.
        # Both maps are the same static (n, s)-cached array; only the slot
        # permutation (and its scatter-add) is per-round work.
        src = group_of = jnp.asarray(_resampling_src(n, s))
        perm = jnp.arange(total) if key is None else jax.random.permutation(key, total)
        mat = jnp.zeros((n, n), jnp.float32)
        # slot t holds replica perm[t] of input src[perm[t]], feeding group_of[t]
        mat = mat.at[group_of, src[perm]].add(1.0 / s)
        return mat


def get_mixer(name: str, s: int = 2) -> Mixer:
    name = (name or "none").lower()
    if name in ("none", "identity", "no", ""):
        return NoMix()
    if name == "bucketing":
        return Bucketing(s)
    if name == "resampling":
        return Resampling(s)
    if name == "fixed_grouping":
        return FixedGrouping(s)
    raise KeyError(f"unknown mixer {name!r}")
