"""Pallas TPU kernel: apply the paper's mixing operator ``y = M @ x``.

Bucketing/resampling (Algorithm 1) is a row-stochastic ``[m, W]`` matrix
applied to the stacked worker gradients. The matrix is tiny and replicated;
the gradient dimension streams through VMEM in 128-aligned blocks, so the
mix costs exactly one read + one write of HBM — it fuses the permute,
bucket-average and (optional) replication of Algorithm 1 into a single pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mix_kernel(m_ref, x_ref, out_ref):
    m = m_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    out_ref[...] = jax.lax.dot_general(
        m, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def bucket_mix(mix: jnp.ndarray, xs: jnp.ndarray, *, block_d: int = 2048,
               interpret: bool = True):
    """mix: [m, W] row-stochastic; xs: [W, d] -> mixed [m, d] fp32."""
    m, W = mix.shape
    W2, d = xs.shape
    assert W == W2, (mix.shape, xs.shape)
    mp = max(8, -(-m // 8) * 8)
    Wp = max(8, -(-W // 8) * 8)
    bd = min(block_d, max(128, -(-d // 128) * 128))
    bd = -(-bd // 128) * 128
    dp = -(-d // bd) * bd
    mx = jnp.zeros((mp, Wp), jnp.float32).at[:m, :W].set(mix.astype(jnp.float32))
    x = jnp.zeros((Wp, dp), xs.dtype).at[:W, :d].set(xs)

    out = pl.pallas_call(
        _mix_kernel,
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((mp, Wp), lambda k: (0, 0)),
            pl.BlockSpec((Wp, bd), lambda k: (0, k)),
        ],
        out_specs=pl.BlockSpec((mp, bd), lambda k: (0, k)),
        out_shape=jax.ShapeDtypeStruct((mp, dp), jnp.float32),
        interpret=interpret,
    )(mx, x)
    return out[:m, :d]
