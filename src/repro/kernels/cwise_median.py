"""Pallas TPU kernel: coordinate-wise median over the worker axis.

CM aggregates n <= 64 worker vectors per coordinate. On GPU this is a
per-thread selection; the TPU-native adaptation (DESIGN.md §3) keeps the
worker axis resident in sublanes and runs an **odd-even transposition sort**
— W rounds of vectorized compare-exchange (min/max) over [1, bd] rows, a
pure VPU workload with no data-dependent control flow. The sort network is
fully unrolled at trace time (W is static and small), so Mosaic sees only
static slices.

Padding rows are +inf so they sort to the bottom and never cross the median
index.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sorted_rows(x: jnp.ndarray, W: int) -> jnp.ndarray:
    """Odd-even transposition sort of the first W rows of x (ascending)."""
    rows = [x[i] for i in range(W)]
    for r in range(W):
        start = r % 2
        for i in range(start, W - 1, 2):
            lo = jnp.minimum(rows[i], rows[i + 1])
            hi = jnp.maximum(rows[i], rows[i + 1])
            rows[i], rows[i + 1] = lo, hi
    return rows


def _median_kernel(x_ref, out_ref, *, W: int):
    x = x_ref[...].astype(jnp.float32)  # [Wp, bd]
    rows = _sorted_rows(x, W)
    mid = W // 2
    if W % 2 == 1:
        med = rows[mid]
    else:
        med = 0.5 * (rows[mid - 1] + rows[mid])
    out_ref[...] = med[None, :]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def cwise_median(xs: jnp.ndarray, *, block_d: int = 1024, interpret: bool = True):
    """xs: [W, d] -> median over workers [d] fp32."""
    W, d = xs.shape
    Wp = max(8, -(-W // 8) * 8)
    bd = min(block_d, max(128, -(-d // 128) * 128))
    bd = -(-bd // 128) * 128
    dp = -(-d // bd) * bd
    x = jnp.full((Wp, dp), jnp.inf, jnp.float32).at[:W, :d].set(
        xs.astype(jnp.float32)
    )

    out = pl.pallas_call(
        functools.partial(_median_kernel, W=W),
        grid=(dp // bd,),
        in_specs=[pl.BlockSpec((Wp, bd), lambda k: (0, k))],
        out_specs=pl.BlockSpec((1, bd), lambda k: (0, k)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=interpret,
    )(x)
    return out[0, :d]
