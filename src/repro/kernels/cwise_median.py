"""Pallas TPU kernel: coordinate-wise median over the worker axis.

CM aggregates n <= 64 worker vectors per coordinate. On GPU this is a
per-thread selection; the TPU-native adaptation (DESIGN.md §3) keeps the
worker axis resident in sublanes and runs a **pruned Batcher odd-even merge
selection network** (repro/kernels/selection_network.py) — a static
compare-exchange program that materializes only the 1-2 middle order
statistics, vectorized min/max over [1, bd] rows, a pure VPU workload with
no data-dependent control flow. The program is built from static (W, ranks)
and fully unrolled at trace time, so Mosaic sees only static slices; it
replaces the old O(W^2) odd-even transposition sort (W=25: 113 comparators
vs 312).

Padding rows exist only for the sublane-aligned BlockSpec; the selection
program never references slots >= W (sentinel elimination), so their +inf
fill is never read.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.selection_network import (
    apply_program,
    median_ranks,
    selection_program,
)


def _median_kernel(x_ref, out_ref, *, W: int):
    x = x_ref[...].astype(jnp.float32)  # [Wp, bd]
    ranks = median_ranks(W)
    rows = apply_program([x[i] for i in range(W)],
                         selection_program(W, ranks))
    if len(ranks) == 1:
        med = rows[ranks[0]]
    else:
        med = 0.5 * (rows[ranks[0]] + rows[ranks[1]])
    out_ref[...] = med[None, :]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def cwise_median(xs: jnp.ndarray, *, block_d: int = 4096, interpret: bool = True):
    """xs: [W, d] -> median over workers [d] fp32."""
    W, d = xs.shape
    Wp = max(8, -(-W // 8) * 8)
    if interpret:
        # interpret mode pays one traced-op dispatch per comparator per grid
        # step, so fewer/wider blocks dominate; VMEM tiling only binds on a
        # real TPU (interpret=False). Cap the block to bound the buffer.
        block_d = max(block_d, min(-(-d // 128) * 128, 1 << 20))
    bd = min(block_d, max(128, -(-d // 128) * 128))
    bd = -(-bd // 128) * 128
    dp = -(-d // bd) * bd
    x = jnp.full((Wp, dp), jnp.inf, jnp.float32).at[:W, :d].set(
        xs.astype(jnp.float32)
    )

    out = pl.pallas_call(
        functools.partial(_median_kernel, W=W),
        grid=(dp // bd,),
        in_specs=[pl.BlockSpec((Wp, bd), lambda k: (0, k))],
        out_specs=pl.BlockSpec((1, bd), lambda k: (0, k)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=interpret,
    )(x)
    return out[0, :d]
