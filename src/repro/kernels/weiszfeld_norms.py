"""Pallas TPU kernel: per-worker residual norms ``r_i = ||x_i - c^T X||^2``.

The inner loop of smoothed Weiszfeld (RFA) and of CCLIP's Gram-free form:
given combination coefficients ``c`` for the current iterate ``v = c^T X``,
compute every worker's squared distance to ``v`` in ONE streaming pass —
the candidate ``v`` is formed blockwise in VMEM (``c @ x_blk``) and
subtracted immediately, so ``v`` never round-trips to HBM. A fused
(matvec + subtract + square + row-reduce) pass.

Padding: extra worker rows are zero, producing garbage residuals that the
wrapper slices off; extra d columns are zero in both x and v, contributing 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _resid_kernel(c_ref, x_ref, out_ref):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)          # [Wp, bd]
    c = c_ref[...].astype(jnp.float32)          # [1, Wp]
    v = jax.lax.dot_general(                    # [1, bd]
        c, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    diff = x - v
    out_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True).T  # [1, Wp]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def residual_norms(xs: jnp.ndarray, coeffs: jnp.ndarray, *, block_d: int = 2048,
                   interpret: bool = True):
    """xs: [W, d]; coeffs: [W] -> residual sq norms [W] fp32."""
    W, d = xs.shape
    Wp = max(8, -(-W // 8) * 8)
    bd = min(block_d, max(128, -(-d // 128) * 128))
    bd = -(-bd // 128) * 128
    dp = -(-d // bd) * bd
    x = jnp.zeros((Wp, dp), xs.dtype).at[:W, :d].set(xs)
    c = jnp.zeros((1, Wp), jnp.float32).at[0, :W].set(coeffs.astype(jnp.float32))

    out = pl.pallas_call(
        _resid_kernel,
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((1, Wp), lambda k: (0, 0)),
            pl.BlockSpec((Wp, bd), lambda k: (0, k)),
        ],
        out_specs=pl.BlockSpec((1, Wp), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, Wp), jnp.float32),
        interpret=interpret,
    )(c, x)
    return out[0, :W]
