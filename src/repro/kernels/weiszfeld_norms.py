"""Pallas TPU kernel: per-worker residual norms ``r_i = ||x_i - v||^2``.

The inner loop of smoothed Weiszfeld (RFA) and of CCLIP's Gram-free form.
The center ``v`` is given either

- in COEFFICIENT form (``coeffs``): ``v = c^T X`` for combination
  coefficients ``c`` over the worker rows. The candidate ``v`` is formed
  blockwise in VMEM (``c @ x_blk``) and subtracted immediately, so ``v``
  never round-trips to HBM. A fused (matvec + subtract + square +
  row-reduce) pass; or
- as an EXPLICIT row (``center``): an arbitrary ``[d]`` vector streamed
  block-aligned with ``xs``. This is what CCLIP's warm-started iterations
  need — callers no longer have to append ``v`` to the stack as a
  pseudo-row (which cost a full ``jnp.concatenate`` copy of the stack per
  iteration before this existed).

Padding: extra worker rows are zero, producing garbage residuals that the
wrapper slices off; extra d columns are zero in both x and v, contributing 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _resid_kernel(c_ref, x_ref, out_ref):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)          # [Wp, bd]
    c = c_ref[...].astype(jnp.float32)          # [1, Wp]
    v = jax.lax.dot_general(                    # [1, bd]
        c, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    diff = x - v
    out_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True).T  # [1, Wp]


def _resid_center_kernel(v_ref, x_ref, out_ref):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)          # [Wp, bd]
    v = v_ref[...].astype(jnp.float32)          # [1, bd]
    diff = x - v
    out_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True).T  # [1, Wp]


def _pad_dims(W, d, block_d):
    Wp = max(8, -(-W // 8) * 8)
    bd = min(block_d, max(128, -(-d // 128) * 128))
    bd = -(-bd // 128) * 128
    dp = -(-d // bd) * bd
    return Wp, bd, dp


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def residual_norms(xs: jnp.ndarray, coeffs: jnp.ndarray | None = None, *,
                   center: jnp.ndarray | None = None, block_d: int = 2048,
                   interpret: bool = True):
    """xs: [W, d] -> residual sq norms [W] fp32 against the center given
    either as ``coeffs: [W]`` (``v = coeffs^T xs``) or as an explicit
    ``center: [d]`` row. Exactly one of the two must be provided."""
    if (coeffs is None) == (center is None):
        raise ValueError("provide exactly one of coeffs / center")
    W, d = xs.shape
    Wp, bd, dp = _pad_dims(W, d, block_d)
    x = jnp.zeros((Wp, dp), xs.dtype).at[:W, :d].set(xs)

    if coeffs is not None:
        first = jnp.zeros((1, Wp), jnp.float32).at[0, :W].set(
            coeffs.astype(jnp.float32))
        kernel = _resid_kernel
        first_spec = pl.BlockSpec((1, Wp), lambda k: (0, 0))
    else:
        first = jnp.zeros((1, dp), jnp.float32).at[0, :d].set(
            center.astype(jnp.float32))
        kernel = _resid_center_kernel
        first_spec = pl.BlockSpec((1, bd), lambda k: (0, k))

    out = pl.pallas_call(
        kernel,
        grid=(dp // bd,),
        in_specs=[
            first_spec,
            pl.BlockSpec((Wp, bd), lambda k: (0, k)),
        ],
        out_specs=pl.BlockSpec((1, Wp), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, Wp), jnp.float32),
        interpret=interpret,
    )(first, x)
    return out[0, :W]
