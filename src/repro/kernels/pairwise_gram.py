"""Pallas TPU kernel: worker Gram matrix ``G[i,j] = <x_i, x_j>``.

The stats phase of Krum / RFA / CCLIP (DESIGN.md §4) is a rank-``d``
reduction of outer products — a natural MXU workload. The parameter
dimension is tiled into VMEM-resident ``[W, bd]`` blocks (``bd`` a multiple
of 128 so the contraction dim is MXU-aligned); the tiny ``[W, W]`` fp32
accumulator lives in the output block across the whole grid (revisited every
step, standard Pallas accumulation pattern).

HBM traffic: ``W*d`` input bytes read exactly once — the kernel is
memory-bound (arithmetic intensity W/2 FLOPs/byte), so the roofline target
is HBM bandwidth, which one-pass streaming achieves.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(x_ref, out_ref):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)
    out_ref[...] += jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def pairwise_gram(xs: jnp.ndarray, *, block_d: int = 2048, interpret: bool = True):
    """xs: [W, d] (any float dtype) -> Gram [W, W] fp32.

    Pads W to a multiple of 8 (sublane) and d to a multiple of the block
    (lane=128-aligned); zero padding contributes 0 to every inner product.
    """
    W, d = xs.shape
    Wp = max(8, -(-W // 8) * 8)
    bd = min(block_d, max(128, -(-d // 128) * 128))
    bd = -(-bd // 128) * 128
    dp = -(-d // bd) * bd
    x = jnp.zeros((Wp, dp), xs.dtype).at[:W, :d].set(xs)

    out = pl.pallas_call(
        _gram_kernel,
        grid=(dp // bd,),
        in_specs=[pl.BlockSpec((Wp, bd), lambda k: (0, k))],
        out_specs=pl.BlockSpec((Wp, Wp), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((Wp, Wp), jnp.float32),
        interpret=interpret,
    )(x)
    return out[:W, :W]
