"""Pallas TPU kernel: worker Gram matrix ``G[i,j] = <x_i, x_j>``.

The stats phase of Krum / RFA / CCLIP (DESIGN.md §4) is a rank-``d``
reduction of outer products — a natural MXU workload. The parameter
dimension is tiled into VMEM-resident ``[W, bd]`` blocks (``bd`` a multiple
of 128 so the contraction dim is MXU-aligned); the tiny ``[W, W]`` fp32
accumulator lives in the output block across the whole grid (revisited every
step, standard Pallas accumulation pattern).

HBM traffic: ``W*d`` input bytes read exactly once — the kernel is
memory-bound (arithmetic intensity W/2 FLOPs/byte), so the roofline target
is HBM bandwidth, which one-pass streaming achieves.

Chained accumulation (``acc``): the kernel can seed its accumulator from a
caller-supplied ``[W, W]`` matrix instead of zeros. Together with
``full_blocks=True`` (force every block to exactly ``block_d`` columns)
this makes a CHAIN of per-leaf calls perform the *identical* sequence of
block dots and fp32 adds as ONE call on the packed flat buffer whose leaf
segments are padded to ``block_d`` multiples — the bit-exactness bridge
between the per-leaf oracle and the packed engine
(repro/distributed/packing.py, asserted in tests/test_packing.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(acc_ref, x_ref, out_ref):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = acc_ref[...].astype(jnp.float32)

    x = x_ref[...].astype(jnp.float32)
    out_ref[...] += jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_d", "interpret", "full_blocks"))
def pairwise_gram(xs: jnp.ndarray, acc: jnp.ndarray | None = None, *,
                  block_d: int = 2048, interpret: bool = True,
                  full_blocks: bool = False):
    """xs: [W, d] (any float dtype) -> Gram [W, W] fp32 (``acc +`` if given).

    Pads W to a multiple of 8 (sublane) and d to a multiple of the block
    (lane=128-aligned); zero padding contributes 0 to every inner product.
    ``full_blocks`` forces the block width to exactly ``block_d`` (padding d
    up to a ``block_d`` multiple) so block shapes are independent of ``d``.
    """
    W, d = xs.shape
    Wp = max(8, -(-W // 8) * 8)
    if full_blocks:
        bd = -(-block_d // 128) * 128
    else:
        bd = min(block_d, max(128, -(-d // 128) * 128))
        bd = -(-bd // 128) * 128
    dp = max(bd, -(-d // bd) * bd)
    x = jnp.zeros((Wp, dp), xs.dtype).at[:W, :d].set(xs)
    a = jnp.zeros((Wp, Wp), jnp.float32)
    if acc is not None:
        a = a.at[:W, :W].set(acc.astype(jnp.float32))

    out = pl.pallas_call(
        _gram_kernel,
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((Wp, Wp), lambda k: (0, 0)),
            pl.BlockSpec((Wp, bd), lambda k: (0, k)),
        ],
        out_specs=pl.BlockSpec((Wp, Wp), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((Wp, Wp), jnp.float32),
        interpret=interpret,
    )(a, x)
    return out[:W, :W]
