"""Pallas TPU kernel: coordinate-wise trimmed mean over the worker axis.

Same engine as cwise_median: a pruned Batcher odd-even merge selection
network (repro/kernels/selection_network.py) materializes the sorted
``[b, W-b)`` band per coordinate with static vectorized min/max
compare-exchanges, then averages the band in one pass. ``n_trim == 0``
skips the network entirely (a mean is order-free). Fully unrolled at trace
time; padding rows exist only for the sublane-aligned BlockSpec and are
never read (the program references no slot >= W).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.selection_network import (
    apply_program,
    selection_program,
    trim_ranks,
)


def _tm_kernel(x_ref, out_ref, *, W: int, n_trim: int):
    x = x_ref[...].astype(jnp.float32)  # [Wp, bd]
    rows = [x[i] for i in range(W)]
    if n_trim > 0:
        ranks = trim_ranks(W, n_trim)
        sorted_rows = apply_program(rows, selection_program(W, ranks))
        band = [sorted_rows[r] for r in ranks]
    else:
        band = rows
    acc = band[0]
    for row in band[1:]:
        acc = acc + row
    out_ref[...] = (acc / float(len(band)))[None, :]


@functools.partial(jax.jit, static_argnames=("n_trim", "block_d", "interpret"))
def cwise_trimmed_mean(xs: jnp.ndarray, n_trim: int, *, block_d: int = 4096,
                       interpret: bool = True):
    """xs: [W, d] -> mean of the sorted [n_trim, W-n_trim) worker band, [d]
    fp32. ``n_trim`` must satisfy ``0 <= n_trim <= (W - 1) // 2`` (callers
    clamp; asserted here because the band must be non-empty)."""
    W, d = xs.shape
    if not 0 <= n_trim <= (W - 1) // 2:
        raise ValueError(f"n_trim={n_trim} out of range for W={W}")
    Wp = max(8, -(-W // 8) * 8)
    if interpret:
        # one wide block per dispatch batch — see cwise_median.py; VMEM
        # tiling only binds on a real TPU (interpret=False).
        block_d = max(block_d, min(-(-d // 128) * 128, 1 << 20))
    bd = min(block_d, max(128, -(-d // 128) * 128))
    bd = -(-bd // 128) * 128
    dp = -(-d // bd) * bd
    x = jnp.full((Wp, dp), jnp.inf, jnp.float32).at[:W, :d].set(
        xs.astype(jnp.float32)
    )

    out = pl.pallas_call(
        functools.partial(_tm_kernel, W=W, n_trim=n_trim),
        grid=(dp // bd,),
        in_specs=[pl.BlockSpec((Wp, bd), lambda k: (0, k))],
        out_specs=pl.BlockSpec((1, bd), lambda k: (0, k)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=interpret,
    )(x)
    return out[0, :d]
