"""Pallas TPU kernel: fused centered-clipping iteration (combine + norms).

One CCLIP step with the clip weights ``lam`` already known does

    v' = v + (1/W) sum_i lam_i (x_i - v)          (combine)
    r_i' = ||x_i - v'||^2                          (norms for the NEXT lam)

Both are streamed in a SINGLE pass over the ``[W, d]`` stack: each ``bd``
block of ``v'`` is formed in VMEM (``lam @ (x_blk - v_blk)``), written out,
and immediately reused to accumulate the next iteration's residual norms —
so per CCLIP iteration the gradients leave HBM exactly once, instead of the
pre-fusion schedule of one norms kernel over a ``[W+1, d]`` pseudo-row stack
(built by a full `jnp.concatenate` copy) plus one combine kernel, i.e. one
HBM pass instead of two passes and a stack-sized copy.

Padding rows carry lam = 0 and x = 0, so they contribute exactly 0 to the
update; their residuals are garbage and are sliced off by the wrapper.
Padded d columns are zero in x and v, stay zero in v', and contribute 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_kernel(lam_ref, v_ref, x_ref, vout_ref, r2_ref, *, W: int):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        r2_ref[...] = jnp.zeros_like(r2_ref)

    lam = lam_ref[...].astype(jnp.float32)      # [1, Wp]
    v = v_ref[...].astype(jnp.float32)          # [1, bd]
    x = x_ref[...].astype(jnp.float32)          # [Wp, bd]
    upd = jax.lax.dot_general(                  # [1, bd] = lam @ (x - v)
        lam, x - v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    v_new = v + upd / W
    vout_ref[...] = v_new
    diff = x - v_new
    r2_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True).T  # [1, Wp]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def cclip_fused_iter(xs: jnp.ndarray, v: jnp.ndarray, lam: jnp.ndarray, *,
                     block_d: int = 2048, interpret: bool = True):
    """xs: [W, d]; v: [d]; lam: [W] -> (v' [d] fp32, ||x_i - v'||^2 [W] fp32)."""
    W, d = xs.shape
    Wp = max(8, -(-W // 8) * 8)
    bd = min(block_d, max(128, -(-d // 128) * 128))
    bd = -(-bd // 128) * 128
    dp = -(-d // bd) * bd
    x = jnp.zeros((Wp, dp), xs.dtype).at[:W, :d].set(xs)
    vp = jnp.zeros((1, dp), jnp.float32).at[0, :d].set(v.astype(jnp.float32))
    lm = jnp.zeros((1, Wp), jnp.float32).at[0, :W].set(lam.astype(jnp.float32))

    v_new, r2 = pl.pallas_call(
        functools.partial(_fused_kernel, W=W),
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((1, Wp), lambda k: (0, 0)),
            pl.BlockSpec((1, bd), lambda k: (0, k)),
            pl.BlockSpec((Wp, bd), lambda k: (0, k)),
        ],
        out_specs=[
            pl.BlockSpec((1, bd), lambda k: (0, k)),
            pl.BlockSpec((1, Wp), lambda k: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, dp), jnp.float32),
            jax.ShapeDtypeStruct((1, Wp), jnp.float32),
        ],
        interpret=interpret,
    )(lm, vp, x)
    return v_new[0, :d], r2[0, :W]
