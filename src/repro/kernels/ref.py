"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are validated against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose in
interpret mode). They are also the CPU fallback used by ``ops.py`` when the
backend cannot lower Pallas.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_gram(xs: jnp.ndarray) -> jnp.ndarray:
    """Worker Gram matrix. xs: [W, d] -> [W, W] fp32."""
    x32 = xs.astype(jnp.float32)
    return x32 @ x32.T


def cwise_median(xs: jnp.ndarray) -> jnp.ndarray:
    """Coordinate-wise median over the worker axis. [W, d] -> [d] (fp32)."""
    return jnp.median(xs.astype(jnp.float32), axis=0)


def cwise_trimmed_mean(xs: jnp.ndarray, n_trim: int) -> jnp.ndarray:
    """Mean of the sorted [n_trim, W-n_trim) worker band. [W, d] -> [d] fp32."""
    s = jnp.sort(xs.astype(jnp.float32), axis=0)
    return jnp.mean(s[n_trim: xs.shape[0] - n_trim], axis=0)


def bucket_mix(mix: jnp.ndarray, xs: jnp.ndarray) -> jnp.ndarray:
    """Apply the mixing operator: [m, W] @ [W, d] -> [m, d] fp32."""
    return mix.astype(jnp.float32) @ xs.astype(jnp.float32)


def residual_norms(xs: jnp.ndarray, coeffs: jnp.ndarray) -> jnp.ndarray:
    """Per-worker residual sq-norms ``r_i = ||x_i - c^T X||^2``. -> [W] fp32."""
    x32 = xs.astype(jnp.float32)
    v = coeffs.astype(jnp.float32) @ x32
    return jnp.sum(jnp.square(x32 - v[None, :]), axis=1)


def cclip_combine(xs: jnp.ndarray, v: jnp.ndarray, lam: jnp.ndarray) -> jnp.ndarray:
    """One centered-clipping update: ``v + mean_i lam_i (x_i - v)``. -> [d] fp32."""
    x32 = xs.astype(jnp.float32)
    v32 = v.astype(jnp.float32)
    return v32 + jnp.mean(lam.astype(jnp.float32)[:, None] * (x32 - v32[None, :]), axis=0)


# ------------------------------------------------- composed aggregator refs
def cclip_aggregate(xs: jnp.ndarray, tau: float, n_iters: int = 3, eps: float = 1e-12):
    """Full CCLIP in vector space (oracle for ops.cclip_aggregate)."""
    x32 = xs.astype(jnp.float32)
    v = jnp.mean(x32, axis=0)
    for _ in range(n_iters):
        norms = jnp.sqrt(jnp.sum(jnp.square(x32 - v[None, :]), axis=1) + eps)
        lam = jnp.minimum(1.0, tau / norms)
        v = cclip_combine(x32, v, lam)
    return v


def rfa_aggregate(xs: jnp.ndarray, n_iters: int = 8, eps: float = 1e-6):
    """Smoothed Weiszfeld in vector space (oracle for ops.rfa_aggregate)."""
    x32 = xs.astype(jnp.float32)
    n = xs.shape[0]
    c = jnp.full((n,), 1.0 / n, jnp.float32)
    for _ in range(n_iters):
        r = jnp.sqrt(residual_norms(x32, c) + eps**2)
        w = 1.0 / r
        c = w / jnp.sum(w)
    return c @ x32


def attention(q, k, v, causal=True, window=0, q_offset=None):
    """Oracle for flash_attention. q: [B,Sq,H,dh]; k,v: [B,Skv,KV,dh]."""
    B, Sq, H, dh = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    rep = H // KV
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    off = Skv - Sq if q_offset is None else q_offset
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * dh ** -0.5
    qpos = off + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
