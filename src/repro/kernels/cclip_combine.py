"""Pallas TPU kernel: fused centered-clipping update.

One CCLIP iteration ``v' = v + (1/W) sum_i lam_i (x_i - v)`` with the clip
weights ``lam`` already known (from ``weiszfeld_norms``): a fused
scale-subtract-accumulate streaming over the parameter dimension. Together
with the norms kernel this makes one CCLIP iteration exactly TWO HBM passes
over the ``W x d`` gradients (norms pass + combine pass) and zero
materialized temporaries.

Padding rows carry lam = 0 and x = 0, so they contribute exactly 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(lam_ref, v_ref, x_ref, out_ref, *, W: int):
    lam = lam_ref[...].astype(jnp.float32)      # [1, Wp]
    v = v_ref[...].astype(jnp.float32)          # [1, bd]
    x = x_ref[...].astype(jnp.float32)          # [Wp, bd]
    upd = jax.lax.dot_general(                  # [1, bd] = lam @ (x - v)
        lam, x - v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    out_ref[...] = v + upd / W


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def cclip_combine(xs: jnp.ndarray, v: jnp.ndarray, lam: jnp.ndarray, *,
                  block_d: int = 2048, interpret: bool = True):
    """xs: [W, d]; v: [d]; lam: [W] -> updated center [d] fp32."""
    W, d = xs.shape
    Wp = max(8, -(-W // 8) * 8)
    bd = min(block_d, max(128, -(-d // 128) * 128))
    bd = -(-bd // 128) * 128
    dp = -(-d // bd) * bd
    x = jnp.zeros((Wp, dp), xs.dtype).at[:W, :d].set(xs)
    vp = jnp.zeros((1, dp), jnp.float32).at[0, :d].set(v.astype(jnp.float32))
    lm = jnp.zeros((1, Wp), jnp.float32).at[0, :W].set(lam.astype(jnp.float32))

    out = pl.pallas_call(
        functools.partial(_combine_kernel, W=W),
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((1, Wp), lambda k: (0, 0)),
            pl.BlockSpec((1, bd), lambda k: (0, k)),
            pl.BlockSpec((Wp, bd), lambda k: (0, k)),
        ],
        out_specs=pl.BlockSpec((1, bd), lambda k: (0, k)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=interpret,
    )(lm, vp, x)
    return out[0, :d]
