"""Pallas TPU kernel: causal flash attention with GQA (+ sliding window).

The TPU-target fast path for ``repro.models.attention`` (the pure-JAX
``blockwise`` impl is the dry-run/CPU path; both share the same online-
softmax recurrence and are validated against ``ref.attention``).

Grid layout: (batch, q_heads, q_blocks) with the KV loop INSIDE the kernel
(fori_loop over KV blocks) so the running (m, l, acc) state stays in
registers/VMEM — the canonical TPU flash scheme. BlockSpecs stage one
[bq, dh] query tile and the full [Skv, dh] K/V for the mapped kv-head in
VMEM; for the assigned shapes (dh 64-256, Skv <= 32k bf16) that is <= 16 MB
and within v5e VMEM budget when bkv-tiled by the inner loop.

Causal + sliding-window masking is positional (absolute positions passed
per block), so the same kernel serves train (Sq == Skv) and chunked prefill
(Sq < Skv with a prefix offset).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, bkv, causal, window,
                  q_offset):
    # q_ref: [bq, dh]; k_ref/v_ref: [Skv, dh]; o_ref: [bq, dh]
    qi = pl.program_id(2)
    bq, dh = q_ref.shape
    Skv = k_ref.shape[0]
    q = q_ref[...].astype(jnp.float32) * scale
    qpos = q_offset + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    nkv = Skv // bkv

    def body(ki, carry):
        m, l, acc = carry
        k = k_ref[pl.dslice(ki * bkv, bkv), :].astype(jnp.float32)
        v = v_ref[pl.dslice(ki * bkv, bkv), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [bq, bkv]
        kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (1, bkv), 1)
        mask = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_new = acc * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, 1), jnp.float32)
    a0 = jnp.zeros((bq, dh), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nkv, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "q_offset",
                     "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # [B, Sq, H, dh]
    k: jnp.ndarray,  # [B, Skv, KV, dh]
    v: jnp.ndarray,  # [B, Skv, KV, dh]
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    q_offset: int = -1,  # -1 => Skv - Sq (decode-style suffix alignment)
    interpret: bool = True,
) -> jnp.ndarray:
    """Returns [B, Sq, H, dh]. GQA: each query head h reads kv head
    h // (H // KV). Sq must be divisible by block_q and Skv by block_kv
    (callers pick divisor blocks; see models.attention._divisor_block)."""
    B, Sq, H, dh = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    assert Sq % block_q == 0 and Skv % block_kv == 0, (Sq, block_q, Skv, block_kv)
    rep = H // KV
    off = Skv - Sq if q_offset == -1 else q_offset
    scale = dh ** -0.5

    # [B, S, H, dh] -> [B, H, S, dh] so the head becomes a grid dim
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)

    kernel = functools.partial(
        _flash_kernel, scale=scale, bkv=block_kv, causal=causal,
        window=window, q_offset=off,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, Sq // block_q),
        in_specs=[
            pl.BlockSpec((None, None, block_q, dh), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((None, None, Skv, dh),
                         lambda b, h, i, _rep=rep: (b, h // _rep, 0, 0)),
            pl.BlockSpec((None, None, Skv, dh),
                         lambda b, h, i, _rep=rep: (b, h // _rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, dh),
                               lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, dh), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)
