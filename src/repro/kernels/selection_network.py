"""Batcher odd-even-merge selection networks (the order-statistic engine).

The coordinate-wise aggregators (median, trimmed mean) need a handful of
order statistics of W worker values per coordinate, with W static and small
(<= 64). A data-oblivious compare-exchange network keeps the whole
computation branch-free vectorized min/max over ``[d]`` rows — the same
shape Mosaic wants on TPU and XLA fuses into one elementwise loop on CPU —
but the previous odd-even *transposition* network cost O(W^2) comparators
(300 for W=25). This module generates Batcher's odd-even merge sort
(O(W log^2 W): 63 comparators at W=16, 191 at W=32, 543 at W=64) and then
shrinks it twice:

1. **Sentinel elimination.** Batcher networks are defined for power-of-two
   sizes; W is padded to P with +inf sentinels in slots W..P-1. Because
   every comparator routes the min to its lower slot index, a slot >= W
   holds +inf at every point of the schedule, so any comparator touching a
   sentinel slot is a no-op: the P-network restricted to pairs with
   ``j < W`` sorts the W real rows without the sentinels ever existing.

2. **Rank pruning.** Walking the remaining program backwards, a comparator
   is kept only if one of its output slots feeds a later kept comparator or
   is itself a requested order statistic; both of its input slots then
   become needed. Median keeps the middle 1-2 ranks, trimmed mean the
   ``[b, W-b)`` band — e.g. W=25 median needs 93 comparators instead of 300.

Programs are pure Python tuples built from static (W, ranks) and cached, so
both the Pallas kernels and the jnp aggregators unroll the identical static
compare-exchange sequence at trace time (this is what makes the packed and
per-leaf engines bit-exact).

Note jnp.minimum/jnp.maximum propagate NaN from either input, matching the
previous transposition network (NaN inputs were never sorted correctly by
either; callers feed finite gradients).
"""

from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax.numpy as jnp

Pair = Tuple[int, int]


def _oems_pairs(n: int) -> List[Pair]:
    """Comparator list of Batcher's odd-even merge sort for power-of-two n,
    in schedule order; every pair (i, j) has i < j (min routed to i)."""
    pairs: List[Pair] = []

    def merge(lo: int, hi: int, r: int) -> None:
        step = r * 2
        if step < hi - lo:
            merge(lo, hi, step)
            merge(lo + r, hi, step)
            for i in range(lo + r, hi - r, step):
                pairs.append((i, i + r))
        else:
            pairs.append((lo, lo + r))

    def sort(lo: int, hi: int) -> None:  # inclusive bounds
        if hi - lo >= 1:
            mid = lo + (hi - lo) // 2
            sort(lo, mid)
            sort(mid + 1, hi)
            merge(lo, hi, 1)

    if n > 1:
        sort(0, n - 1)
    return pairs


@functools.lru_cache(maxsize=None)
def selection_program(n_rows: int, ranks: Tuple[int, ...]) -> Tuple[Pair, ...]:
    """Static compare-exchange program that places the requested order
    statistics (``ranks``, ascending 0-based positions of the sorted order)
    of ``n_rows`` values into their slots. Slots outside ``ranks`` hold
    unspecified values after the program runs."""
    if not ranks:
        return ()
    if min(ranks) < 0 or max(ranks) >= n_rows:
        raise ValueError(f"ranks {ranks} out of range for n_rows={n_rows}")
    pow2 = 1 << max(0, (n_rows - 1).bit_length())
    pairs = [(i, j) for (i, j) in _oems_pairs(pow2) if j < n_rows]
    needed = set(ranks)
    kept: List[Pair] = []
    for i, j in reversed(pairs):
        if i in needed or j in needed:
            kept.append((i, j))
            needed.add(i)
            needed.add(j)
    return tuple(reversed(kept))


def apply_program(rows: Sequence[jnp.ndarray], program: Sequence[Pair]):
    """Run a compare-exchange program over a list of same-shape arrays.
    Fully unrolled: each pair is one vectorized min + max."""
    rows = list(rows)
    for i, j in program:
        lo = jnp.minimum(rows[i], rows[j])
        hi = jnp.maximum(rows[i], rows[j])
        rows[i], rows[j] = lo, hi
    return rows


def median_ranks(n_rows: int) -> Tuple[int, ...]:
    mid = n_rows // 2
    return (mid,) if n_rows % 2 else (mid - 1, mid)


def trim_ranks(n_rows: int, n_trim: int) -> Tuple[int, ...]:
    """The ``[b, n_rows - b)`` band kept by the trimmed mean."""
    return tuple(range(n_trim, n_rows - n_trim))


def select_rows(x: jnp.ndarray, ranks: Sequence[int]) -> List[jnp.ndarray]:
    """Order statistics ``ranks`` of ``x`` along axis 0 (each ``x[i]`` may
    have any trailing shape). Returns one array per rank, in rank order."""
    ranks = tuple(ranks)
    rows = apply_program(
        [x[i] for i in range(x.shape[0])], selection_program(x.shape[0], ranks)
    )
    return [rows[r] for r in ranks]


def median_select(x: jnp.ndarray) -> jnp.ndarray:
    """Coordinate-wise median of ``x`` over axis 0 via the pruned network;
    value-equal to ``jnp.median(x, axis=0)`` (same multiset -> same middle)."""
    sel = select_rows(x, median_ranks(x.shape[0]))
    return sel[0] if len(sel) == 1 else 0.5 * (sel[0] + sel[1])


def trimmed_mean_select(x: jnp.ndarray, n_trim: int) -> jnp.ndarray:
    """Coordinate-wise mean of the sorted ``[n_trim, W - n_trim)`` band over
    axis 0. ``n_trim == 0`` skips the network (a mean is order-free)."""
    n = x.shape[0]
    if n_trim == 0:
        return jnp.mean(x, axis=0)
    band = select_rows(x, trim_ranks(n, n_trim))
    acc = band[0]
    for row in band[1:]:
        acc = acc + row
    return acc / float(len(band))
