"""Pallas TPU kernels for the robust-aggregation hot spots (DESIGN.md §3).

The paper's server-side cost is dominated by streaming the ``[W, d]``
stacked worker gradients (d up to 10^12 / n_chips): the Gram stats phase
(Krum/RFA/CCLIP), the coordinate-wise median, the Weiszfeld/CCLIP inner
iterations, and the Algorithm-1 mixing itself. Each is a one- or two-pass
streaming kernel with explicit BlockSpec VMEM tiling; pure-jnp oracles live
in ``ref.py`` and the jit'd public API in ``ops.py``.

Validated with ``interpret=True`` on CPU (Mosaic does not lower on the CPU
backend); on TPU the identical ``pl.pallas_call``s compile natively.
"""

from repro.kernels.bucket_mix import bucket_mix
from repro.kernels.cclip_combine import cclip_combine
from repro.kernels.cclip_fused import cclip_fused_iter
from repro.kernels.cwise_median import cwise_median
from repro.kernels.flash_attention import flash_attention
from repro.kernels.pairwise_gram import pairwise_gram
from repro.kernels.selection_network import selection_program
from repro.kernels.trimmed_mean import cwise_trimmed_mean
from repro.kernels.weiszfeld_norms import residual_norms

__all__ = [
    "bucket_mix",
    "cclip_combine",
    "cclip_fused_iter",
    "cwise_median",
    "cwise_trimmed_mean",
    "flash_attention",
    "pairwise_gram",
    "residual_norms",
    "selection_program",
]
