"""jit'd public wrappers over the Pallas kernels.

``interpret`` defaults to True off-TPU (the kernels execute their bodies in
Python/XLA on CPU — this is how the container validates them); on a real TPU
backend the same calls lower through Mosaic.

The composed aggregators here are the kernel-accelerated counterparts of
``repro.core.aggregators`` (oracles in ``ref.py``; equivalence is asserted
in tests/test_kernels.py):

  gram(xs, acc=...)           stats phase for Krum / RFA / CCLIP
  cm_aggregate(xs)            full coordinate-wise median
  tm_aggregate(xs, n_trim)    coordinate-wise trimmed mean (sorted band)
  mix_apply(M, xs)            bucketing / resampling application
  norms(xs, c | center=v)     residual sq-norms (Weiszfeld / CCLIP inner loop)
  cclip_iter(xs, v, lam)      one fused CCLIP iteration (combine + next norms)
  rfa_aggregate(xs)           smoothed Weiszfeld via fused residual-norm passes
  cclip_aggregate(xs, tau)    centered clipping, ONE fused HBM pass/iteration

Everything here is SINGLE-DEVICE: inside a jit, GSPMD cannot partition a
``pallas_call``, so on a multi-device mesh these wrappers would run the
whole array on every device. The mesh-partitioned counterparts (each device
running the kernel on its local column slice, with explicit psums for the
reducing phases) live in ``repro.distributed.shard_kernels``.

``cclip_aggregate`` runs each iteration through ``cclip_fused_iter``
(combine + next-iteration norms in one streaming pass); the pre-fusion
two-kernel schedule is kept as ``cclip_aggregate_unfused`` — it is the
benchmark baseline in benchmarks/agg_microbench.py and documents what the
fusion saves (a norms pass over a ``[W+1, d]`` pseudo-row stack built by a
full `jnp.concatenate` copy, plus a separate combine pass).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bucket_mix import bucket_mix
from repro.kernels.cclip_combine import cclip_combine
from repro.kernels.cclip_fused import cclip_fused_iter
from repro.kernels.cwise_median import cwise_median
from repro.kernels.pairwise_gram import pairwise_gram
from repro.kernels.trimmed_mean import cwise_trimmed_mean
from repro.kernels.weiszfeld_norms import residual_norms


def _interp() -> bool:
    return jax.default_backend() != "tpu"


def gram(xs: jnp.ndarray, acc: jnp.ndarray | None = None, *,
         block_d: int = 2048, full_blocks: bool = False) -> jnp.ndarray:
    return pairwise_gram(xs, acc, block_d=block_d, full_blocks=full_blocks,
                         interpret=_interp())


def cm_aggregate(xs: jnp.ndarray, *, block_d: int = 4096) -> jnp.ndarray:
    return cwise_median(xs, block_d=block_d, interpret=_interp())


def tm_aggregate(xs: jnp.ndarray, n_trim: int, *, block_d: int = 4096) -> jnp.ndarray:
    return cwise_trimmed_mean(xs, n_trim, block_d=block_d, interpret=_interp())


def mix_apply(mix: jnp.ndarray, xs: jnp.ndarray, *, block_d: int = 2048) -> jnp.ndarray:
    return bucket_mix(mix, xs, block_d=block_d, interpret=_interp())


def norms(xs: jnp.ndarray, coeffs: jnp.ndarray | None = None, *,
          center: jnp.ndarray | None = None, block_d: int = 2048) -> jnp.ndarray:
    """Residual sq-norms ``||x_i - v||^2`` with v as coeffs or explicit row."""
    return residual_norms(xs, coeffs, center=center, block_d=block_d,
                          interpret=_interp())


def cclip_iter(xs: jnp.ndarray, v: jnp.ndarray, lam: jnp.ndarray, *,
               block_d: int = 2048):
    """One fused CCLIP iteration -> ``(v', ||x_i - v'||^2)``."""
    return cclip_fused_iter(xs, v, lam, block_d=block_d, interpret=_interp())


@functools.partial(jax.jit, static_argnames=("n_iters", "block_d"))
def rfa_aggregate(xs: jnp.ndarray, *, n_iters: int = 8, eps: float = 1e-6,
                  block_d: int = 2048) -> jnp.ndarray:
    """Geometric median of worker rows via kernel-fused Weiszfeld."""
    W = xs.shape[0]
    interp = _interp()

    def body(c, _):
        r2 = residual_norms(xs, c, block_d=block_d, interpret=interp)
        w = 1.0 / jnp.sqrt(r2 + eps**2)
        return w / jnp.sum(w), None

    c0 = jnp.full((W,), 1.0 / W, jnp.float32)
    c, _ = jax.lax.scan(body, c0, None, length=n_iters)
    return mix_apply(c[None, :], xs, block_d=block_d)[0]


@functools.partial(jax.jit, static_argnames=("n_iters", "block_d"))
def cclip_aggregate(xs: jnp.ndarray, tau: float, *, n_iters: int = 3,
                    eps: float = 1e-12, block_d: int = 2048) -> jnp.ndarray:
    """Centered clipping: ONE fused (combine + next-norms) pass per iteration.

    The fused kernel returns ``v'`` together with ``||x_i - v'||^2``, so the
    residuals each iteration needs were already computed while the previous
    update streamed by — only the initial center costs a dedicated norms
    pass (with an explicit center row; no pseudo-row concat).
    """
    W = xs.shape[0]
    interp = _interp()
    v = mix_apply(jnp.full((1, W), 1.0 / W, jnp.float32), xs, block_d=block_d)[0]
    r2 = residual_norms(xs, center=v, block_d=block_d, interpret=interp)

    def body(carry, _):
        v, r2 = carry
        lam = jnp.minimum(1.0, tau / jnp.sqrt(r2 + eps))
        return cclip_fused_iter(xs, v, lam, block_d=block_d, interpret=interp), None

    (v, _), _ = jax.lax.scan(body, (v, r2), None, length=n_iters)
    return v


@functools.partial(jax.jit, static_argnames=("n_iters", "block_d"))
def cclip_aggregate_unfused(xs: jnp.ndarray, tau: float, *, n_iters: int = 3,
                            eps: float = 1e-12, block_d: int = 2048) -> jnp.ndarray:
    """Pre-fusion CCLIP schedule: norms pass + combine pass per iteration,
    with the center appended to the stack as a pseudo-row (a full stack
    copy). Kept as the microbenchmark baseline for ``cclip_aggregate``."""
    W = xs.shape[0]
    interp = _interp()
    v = mix_apply(jnp.full((1, W), 1.0 / W, jnp.float32), xs, block_d=block_d)[0]

    def body(v, _):
        diffs2 = residual_norms(
            jnp.concatenate([xs.astype(jnp.float32), v[None, :]], axis=0),
            jnp.zeros((W + 1,), jnp.float32).at[W].set(1.0),
            block_d=block_d, interpret=interp,
        )[:W]
        lam = jnp.minimum(1.0, tau / jnp.sqrt(diffs2 + eps))
        v_new = cclip_combine(xs, v, lam, block_d=block_d, interpret=interp)
        return v_new, None

    v, _ = jax.lax.scan(body, v, None, length=n_iters)
    return v
