"""Per-worker minibatch pipeline.

Simulation path: datasets are dense arrays ``[n_workers, m, ...]``; each
step draws a per-worker batch with a folded PRNG — pure, jit-able, and
vmap-able over workers. (The distributed path shards the leading worker
axis over the (pod, data) mesh axes; the same sampler runs per-shard.)
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def sample_worker_batches(
    key, data_x: jnp.ndarray, data_y: jnp.ndarray, batch_size: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """data_x: [W, m, ...], data_y: [W, m] -> ([W, B, ...], [W, B])."""
    W, m = data_x.shape[0], data_x.shape[1]
    idx = jax.random.randint(key, (W, batch_size), 0, m)
    bx = jnp.take_along_axis(data_x, idx[..., None], axis=1)
    by = jnp.take_along_axis(data_y, idx, axis=1)
    return bx, by


def sample_token_batches(key, seqs: jnp.ndarray, batch_size: int) -> jnp.ndarray:
    """seqs: [W, n_seqs, L] -> [W, B, L]."""
    W, n, _ = seqs.shape
    idx = jax.random.randint(key, (W, batch_size), 0, n)
    return jnp.take_along_axis(seqs, idx[..., None], axis=1)
