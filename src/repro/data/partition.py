"""Dataset partitioning across workers (paper App. A.1.2).

- ``long_tail_subsample``: class ``i`` keeps a ``gamma^i`` fraction of its
  samples, ``alpha = 1/gamma^(n_classes-1)`` = largest/smallest class ratio
  (paper's alpha = 500 setting).
- ``partition_iid``: shuffle, split evenly.
- ``partition_by_label`` (non-iid): sort by label, split sequentially into
  equal chunks — each good worker sees only 1-2 classes. The last chunk is
  padded from itself (paper A.1.2 step 2).
- Byzantine workers get access to the full dataset (paper A.1.2).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


def long_tail_subsample(x, y, alpha: float, n_classes: int = 10, seed: int = 0):
    """Keep a gamma^i fraction of class i with gamma = alpha^(-1/(C-1))."""
    if alpha <= 1:
        return x, y
    x, y = np.asarray(x), np.asarray(y)
    gamma = alpha ** (-1.0 / (n_classes - 1))
    rng = np.random.RandomState(seed)
    keep_idx = []
    for c in range(n_classes):
        idx = np.where(y == c)[0]
        n_keep = max(1, int(round(len(idx) * gamma**c)))
        keep_idx.append(rng.choice(idx, n_keep, replace=False))
    keep = np.concatenate(keep_idx)
    rng.shuffle(keep)
    return x[keep], y[keep]


def _pad_chunks(chunks, size, rng):
    out = []
    for c in chunks:
        if len(c) < size:
            extra = rng.choice(c, size - len(c), replace=True)
            c = np.concatenate([c, extra])
        out.append(c[:size])
    return np.stack(out)


def partition_iid(n_samples: int, n_workers: int, seed: int = 0) -> np.ndarray:
    """Returns index matrix [n_workers, m]."""
    rng = np.random.RandomState(seed)
    perm = rng.permutation(n_samples)
    m = int(np.ceil(n_samples / n_workers))
    chunks = [perm[i * m : (i + 1) * m] for i in range(n_workers)]
    return _pad_chunks(chunks, m, rng)


def partition_by_label(y, n_workers: int, seed: int = 0) -> np.ndarray:
    """Sort-by-label sequential split (the paper's non-iid partition)."""
    y = np.asarray(y)
    rng = np.random.RandomState(seed)
    order = np.argsort(y, kind="stable")
    m = int(np.ceil(len(y) / n_workers))
    chunks = [order[i * m : (i + 1) * m] for i in range(n_workers)]
    idx = _pad_chunks(chunks, m, rng)
    # paper step 3: shuffle within each worker
    for row in idx:
        rng.shuffle(row)
    return idx


def worker_datasets(
    x, y, n_good: int, n_byz: int, noniid: bool, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """Build per-worker datasets [n_workers, m, ...].

    The training set is divided among the *good* workers only; Byzantine
    workers are given random samples of the whole dataset (they have full
    information per the paper's threat model).
    """
    x, y = np.asarray(x), np.asarray(y)
    if noniid:
        idx = partition_by_label(y, n_good, seed)
    else:
        idx = partition_iid(len(y), n_good, seed)
    m = idx.shape[1]
    rng = np.random.RandomState(seed + 1)
    byz_idx = rng.randint(0, len(y), size=(n_byz, m))
    all_idx = np.concatenate([byz_idx, idx], axis=0)  # byzantine first
    return x[all_idx], y[all_idx]
