"""Synthetic datasets (the container has no dataset downloads).

``make_classification`` builds a seeded 10-class Gaussian-mixture image
dataset ("SynthMNIST", 784-d) whose class structure is learnable by the
paper's MLP; heterogeneity phenomena (sort-by-label partitions, long-tail
class imbalance) are distribution-level and reproduce qualitatively (see
DESIGN.md §7).

``make_token_stream`` builds per-worker token sequences for LLM training:
tokens follow a noisy affine bigram law ``next = (a*tok + b) mod V`` with
per-worker (a, b) "dialects" — heterogeneous workers have different laws,
which yields genuinely non-iid gradients for the Byzantine experiments.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def make_classification(
    key,
    n_samples: int = 10000,
    n_classes: int = 10,
    dim: int = 784,
    class_sep: float = 2.0,
    noise: float = 0.3,
    means_key=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x [N, dim], y [N]) — equal samples per class, shuffled.

    ``means_key`` fixes the class means independently of the sampling key so
    separately-drawn train and test sets share the same task.
    """
    k_means, k_noise, k_perm = jax.random.split(key, 3)
    if means_key is not None:
        k_means = means_key
    means = jax.random.normal(k_means, (n_classes, dim))
    means = means / jnp.linalg.norm(means, axis=1, keepdims=True) * class_sep
    per = n_samples // n_classes
    y = jnp.repeat(jnp.arange(n_classes), per)
    x = means[y] + jax.random.normal(k_noise, (per * n_classes, dim)) * noise
    perm = jax.random.permutation(k_perm, x.shape[0])
    return x[perm], y[perm]


def make_train_test(
    key, n_train: int = 10000, n_test: int = 2000, **kw
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Train/test split sharing class means (the 'SynthMNIST' task)."""
    k_means, k_train, k_test = jax.random.split(key, 3)
    xtr, ytr = make_classification(k_train, n_train, means_key=k_means, **kw)
    xte, yte = make_classification(k_test, n_test, means_key=k_means, **kw)
    return xtr, ytr, xte, yte


def make_token_stream(
    key,
    n_workers: int,
    seq_len: int,
    n_seqs_per_worker: int,
    vocab: int,
    heterogeneous: bool = True,
    noise_p: float = 0.1,
) -> jnp.ndarray:
    """Returns tokens [n_workers, n_seqs, seq_len+1] (inputs + next-token labels).

    Each worker's stream follows ``next = (a_w * tok + b_w) mod V`` with
    probability 1-noise_p (uniform otherwise). Homogeneous mode shares one
    (a, b) across workers.
    """
    k_ab, k_init, k_noise, k_unif = jax.random.split(key, 4)
    n_laws = n_workers if heterogeneous else 1
    a = jax.random.randint(k_ab, (n_laws,), 1, 97) * 2 + 1  # odd multipliers
    b = jax.random.randint(jax.random.fold_in(k_ab, 1), (n_laws,), 0, vocab)
    if not heterogeneous:
        a = jnp.broadcast_to(a, (n_workers,))
        b = jnp.broadcast_to(b, (n_workers,))

    shape = (n_workers, n_seqs_per_worker)
    tok0 = jax.random.randint(k_init, shape, 0, vocab)
    flips = jax.random.bernoulli(k_noise, noise_p, shape + (seq_len,))
    unif = jax.random.randint(k_unif, shape + (seq_len,), 0, vocab)

    def step(tok, inputs):
        flip, u = inputs
        nxt = jnp.mod(a[:, None] * tok + b[:, None], vocab)
        nxt = jnp.where(flip, u, nxt)
        return nxt, tok

    _, toks = jax.lax.scan(
        step, tok0, (jnp.moveaxis(flips, -1, 0), jnp.moveaxis(unif, -1, 0))
    )
    toks = jnp.moveaxis(toks, 0, -1)  # [W, n_seqs, seq_len]
    # append one more step for labels
    last = jnp.mod(a[:, None] * toks[..., -1] + b[:, None], vocab)
    return jnp.concatenate([toks, last[..., None]], axis=-1)
