from repro.data.partition import (
    long_tail_subsample,
    partition_by_label,
    partition_iid,
    worker_datasets,
)
from repro.data.pipeline import sample_token_batches, sample_worker_batches
from repro.data.synthetic import make_classification, make_token_stream, make_train_test

__all__ = [
    "make_classification",
    "make_train_test",
    "make_token_stream",
    "long_tail_subsample",
    "partition_iid",
    "partition_by_label",
    "worker_datasets",
    "sample_worker_batches",
    "sample_token_batches",
]
