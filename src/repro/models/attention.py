"""Attention: GQA with RoPE, optional QKV bias / sliding window, three impls.

- ``xla``: plain einsum softmax attention (small S).
- ``blockwise``: memory-O(S * block) online-softmax attention — a pure-JAX
  flash-attention used for the 32k+ shapes (lax.map over query blocks,
  lax.scan over KV blocks). Numerically identical to ``xla`` up to fp32
  accumulation order.
- Pallas TPU kernel (``repro.kernels.flash_attention``) is the TPU-target
  fast path; the dry-run uses ``blockwise`` because Pallas does not lower on
  the CPU placeholder backend.

Decode path: single-token query against a (possibly windowed) KV cache.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init

NEG_INF = -1e30


# ------------------------------------------------------------------ params
def init_attention(key, cfg) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    p = {
        "wq": dense_init(ks[0], d, h * dh, dtype),
        "wk": dense_init(ks[1], d, kv * dh, dtype),
        "wv": dense_init(ks[2], d, kv * dh, dtype),
        "wo": dense_init(ks[3], h * dh, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((kv * dh,), dtype)
        p["bv"] = jnp.zeros((kv * dh,), dtype)
    return p


def _project_qkv(p, x, cfg, positions):
    B, S, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, h, dh)
    k = k.reshape(B, S, kv, dh)
    v = v.reshape(B, S, kv, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _expand_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """[B, S, KV, dh] -> [B, S, H, dh] by repeating each kv head."""
    kv = k.shape[2]
    rep = n_heads // kv
    return jnp.repeat(k, rep, axis=2)


# -------------------------------------------------------------- full (xla)
def _attn_xla(q, k, v, scale, causal: bool, window: int):
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


# -------------------------------------------------- blockwise (flash-style)
def _divisor_block(S: int, target: int) -> int:
    """Largest block size <= target dividing S (handles prefix-extended
    sequence lengths like 4096 + n_prefix that break power-of-two tiling)."""
    b = min(target, S)
    while S % b:
        b -= 1
    return b


def _attn_blockwise(q, k, v, scale, causal: bool, window: int, bq: int, bkv: int):
    """Online-softmax attention. q: [B,Sq,H,dh]; k,v: [B,Sk,KV,dh]."""
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    bq = _divisor_block(Sq, bq)
    bkv = _divisor_block(Sk, bkv)
    nq, nk = Sq // bq, Sk // bkv
    rep = H // KV
    qpos_base = Sk - Sq  # causal offset (decode prefix)

    qb = q.reshape(B, nq, bq, H, dh)
    kb = k.reshape(B, nk, bkv, KV, dh)
    vb = v.reshape(B, nk, bkv, KV, dh)

    def one_q_block(args):
        qi, q_blk = args  # q_blk: [B, bq, H, dh]
        qpos = qpos_base + qi * bq + jnp.arange(bq)

        def kv_step(carry, args2):
            m, l, acc = carry
            ki, k_blk, v_blk = args2
            kpos = ki * bkv + jnp.arange(bkv)
            logits = (
                jnp.einsum("bqhd,bkhd->bhqk", q_blk, jnp.repeat(k_blk, rep, axis=2))
                .astype(jnp.float32)
                * scale
            )
            mask = jnp.ones((bq, bkv), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window > 0:
                mask &= kpos[None, :] > qpos[:, None] - window
            logits = jnp.where(mask[None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, jnp.repeat(v_blk, rep, axis=2).astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, bq), jnp.float32)
        a0 = jnp.zeros((B, H, bq, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 1, 2)  # [B, bq, H, dh]

    outs = jax.lax.map(one_q_block, (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, dh).astype(q.dtype)


# ---------------------------------------------------------------- forward
def attention(p, x, cfg, positions, impl: Optional[str] = None) -> jnp.ndarray:
    """Self-attention over the full sequence (train / prefill)."""
    q, k, v = _project_qkv(p, x, cfg, positions)
    scale = cfg.head_dim_ ** -0.5
    impl = impl or cfg.attention_impl
    if impl == "auto":
        impl = "blockwise" if x.shape[1] > 2048 else "xla"
    if impl == "xla":
        out = _attn_xla(q, k, v, scale, True, cfg.sliding_window)
    elif impl == "blockwise":
        out = _attn_blockwise(
            q, k, v, scale, True, cfg.sliding_window, cfg.attn_block_q, cfg.attn_block_kv
        )
    else:
        raise ValueError(f"unknown attention impl {impl!r}")
    B, S = x.shape[:2]
    return out.reshape(B, S, cfg.n_heads * cfg.head_dim_) @ p["wo"]


# ----------------------------------------------------------------- decode
@dataclasses.dataclass
class KVCacheSpec:
    """Static description of one attention layer's cache."""

    length: int  # cache capacity (window or full seq)


def init_kv_cache(batch: int, length: int, cfg, dtype) -> dict:
    kv, dh = cfg.n_kv_heads, cfg.head_dim_
    return {
        "k": jnp.zeros((batch, length, kv, dh), dtype),
        "v": jnp.zeros((batch, length, kv, dh), dtype),
    }


def decode_attention(p, x, cache, cfg, position) -> Tuple[jnp.ndarray, dict]:
    """One-token decode. x: [B, 1, D]; cache k/v: [B, L, KV, dh];
    position: scalar int32 — the absolute position of the new token.

    The cache is a ring buffer of capacity L: slot = position % L. Attention
    masks out unwritten (future-of-window) slots via per-slot positions.
    """
    B = x.shape[0]
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    L = cache["k"].shape[1]
    pos_arr = jnp.full((B, 1), position, jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, cfg, pos_arr)

    slot = jnp.mod(position, L)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))

    # absolute position held in each ring slot (<= position, stride L)
    idx = jnp.arange(L)
    slot_pos = position - jnp.mod(position - idx, L)
    valid = slot_pos >= 0
    if cfg.sliding_window > 0:
        valid &= slot_pos > position - cfg.sliding_window

    scale = dh**-0.5
    k_e = _expand_kv(k, h)
    v_e = _expand_kv(v, h)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k_e).astype(jnp.float32) * scale
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v_e)
    out = out.reshape(B, 1, h * dh) @ p["wo"]
    return out, {"k": k, "v": v}
