"""Mamba-2 (SSD — state-space duality) block, chunked-scan implementation.

Follows the minimal SSD algorithm of Dao & Gu (arXiv:2405.21060): the
sequence is split into chunks of length Q; within a chunk the recurrence is
computed in its dual quadratic (attention-like) form on the MXU, and chunk
boundary states are propagated with a sequential ``lax.scan`` (O(S/Q) steps).
This is the TPU-native adaptation: the quadratic intra-chunk part is a
dense matmul workload, and the inter-chunk scan is tiny ([B, H, P, N]).

Decode: O(1) recurrent state update — the reason the ``long_500k`` shape is
trivially supported for SSM archs.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm


# ------------------------------------------------------------------ params
def init_ssm(key, cfg) -> dict:
    d, din = cfg.d_model, cfg.d_inner
    n, h = cfg.ssm_state, cfg.ssm_heads
    g = 1  # ssm groups
    kconv = cfg.conv_kernel
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * din + 2 * g * n + h  # z, x, B, C, dt
    conv_ch = din + 2 * g * n
    return {
        "in_proj": dense_init(ks[0], d, d_in_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_ch, kconv), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_scale": jnp.ones((din,), dtype),
        "out_proj": dense_init(ks[2], din, d, dtype),
    }


# ---------------------------------------------------------------- helpers
def _segsum_exp(da: jnp.ndarray) -> jnp.ndarray:
    """da: [..., L] -> lower-triangular decay matrix exp(sum_{j<k<=i} da_k).

    L[i, j] = exp(cumsum_i - cumsum_j) for j <= i, else 0.
    """
    cs = jnp.cumsum(da, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    L = da.shape[-1]
    tri = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(tri, jnp.exp(diff), 0.0)


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv as K shifted multiply-adds. x: [B, S, C]; w: [C, K].

    Deliberately NOT lax.conv_general_dilated(feature_group_count=C): XLA
    lowers that conv's filter gradient to a full cross-channel correlation
    (observed: f32[K, B*C, B*C] — 2.8e17 FLOPs for jamba train_4k, 200x the
    whole model; see EXPERIMENTS.md §Perf iteration 1). K is 4: unrolled
    shift-and-add is exact, differentiates cleanly, and is a pure VPU
    (elementwise) workload on TPU — strictly better than a grouped conv.
    """
    K = w.shape[1]
    x32 = x.astype(jnp.float32)
    xp = jnp.pad(x32, ((0, 0), (K - 1, 0), (0, 0)))
    S = x.shape[1]
    out = b.astype(jnp.float32)[None, None, :] + sum(
        xp[:, k : k + S, :] * w[:, k].astype(jnp.float32)[None, None, :]
        for k in range(K)
    )
    return out.astype(x.dtype)


# ------------------------------------------------------------------- train
def ssd_scan(x, dt, A, B_, C_, chunk: int):
    """Chunked SSD. x: [B,S,H,P]; dt: [B,S,H]; A: [H] (negative);
    B_, C_: [B,S,G,N] (G=1). Returns y: [B,S,H,P] and final state [B,H,P,N]."""
    Bsz, S, H, P = x.shape
    N = B_.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    # broadcast groups (G=1) over heads
    Bh = jnp.broadcast_to(B_[:, :, 0:1], (Bsz, S, 1, N))[:, :, 0]  # [B,S,N]
    Ch = jnp.broadcast_to(C_[:, :, 0:1], (Bsz, S, 1, N))[:, :, 0]

    xc = x.reshape(Bsz, nc, Q, H, P).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(jnp.float32)
    Bc = Bh.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    Cc = Ch.reshape(Bsz, nc, Q, N).astype(jnp.float32)

    da = dtc * A[None, None, None, :]  # [B,c,Q,H]
    da_t = jnp.moveaxis(da, -1, -2)  # [B,c,H,Q]
    cs = jnp.cumsum(da_t, axis=-1)  # [B,c,H,Q]
    xdt = xc * dtc[..., None]  # input scaled by dt

    # intra-chunk (quadratic/dual form)
    Lm = _segsum_exp(da_t)  # [B,c,H,Q,Q]
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)  # [B,c,Q,Q]
    y_diag = jnp.einsum("bcls,bchls,bcshp->bclhp", scores, Lm, xdt)

    # chunk-boundary states
    decay_to_end = jnp.exp(cs[..., -1:] - cs)  # [B,c,H,Q]
    states = jnp.einsum("bcsn,bchs,bcshp->bchpn", Bc, decay_to_end, xdt)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cs[..., -1])  # [B,c,H]

    def step(h_prev, inp):
        st, dec = inp  # [B,H,P,N], [B,H]
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    h_last, h_prevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # [B,c,H,P,N], state entering chunk c

    # contribution of carried-in state
    decay_in = jnp.exp(cs)  # [B,c,H,Q]
    y_off = jnp.einsum("bcln,bchpn,bchl->bclhp", Cc, h_prevs, decay_in)

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y, h_last


def ssm_layer(p, hidden, cfg) -> jnp.ndarray:
    """Full Mamba-2 block (train). hidden: [B, S, D]."""
    B, S, D = hidden.shape
    din, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim

    zxbcdt = hidden @ p["in_proj"]
    z, xbc, dt_raw = jnp.split(zxbcdt, [din, din + din + 2 * n], axis=-1)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc)
    x, B_, C_ = jnp.split(xbc, [din, din + n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    A = -jnp.exp(p["A_log"])  # [H]
    xh = x.reshape(B, S, h, P)
    y, _ = ssd_scan(xh, dt, A, B_[:, :, None, :], C_[:, :, None, :], cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, din).astype(hidden.dtype)

    # gated RMSNorm + out projection
    gated = y * jax.nn.silu(z)
    gated = rmsnorm({"scale": p["norm_scale"]}, gated, cfg.norm_eps)
    return gated @ p["out_proj"]


# ------------------------------------------------------------------ decode
def init_ssm_cache(batch: int, cfg, dtype) -> dict:
    din, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = din + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, h, cfg.ssm_head_dim, n), jnp.float32),
    }


def decode_ssm(p, hidden, cache, cfg) -> Tuple[jnp.ndarray, dict]:
    """One-token recurrent step. hidden: [B, 1, D]."""
    B = hidden.shape[0]
    din, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim

    zxbcdt = hidden[:, 0] @ p["in_proj"]  # [B, ...]
    z, xbc, dt_raw = jnp.split(zxbcdt, [din, din + din + 2 * n], axis=-1)

    # conv ring: state holds the previous K-1 inputs
    conv_in = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # [B,K,C]
    conv_out = jnp.einsum("bkc,ck->bc", conv_in.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
    xbc_t = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32)).astype(hidden.dtype)
    new_conv = conv_in[:, 1:]

    x, B_, C_ = jnp.split(xbc_t, [din, din + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None, :])  # [B,H]

    xh = x.reshape(B, h, P).astype(jnp.float32)
    # h' = dA h + dt * x (outer) B ; y = h' . C + D x
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, B_.astype(jnp.float32))
    new_state = cache["ssm"] * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, C_.astype(jnp.float32))
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, din).astype(hidden.dtype)

    gated = y * jax.nn.silu(z)
    gated = rmsnorm({"scale": p["norm_scale"]}, gated, cfg.norm_eps)
    out = (gated @ p["out_proj"])[:, None, :]
    return out, {"conv": new_conv, "ssm": new_state}
