"""Shared neural-net building blocks (pure JAX, functional).

Parameters are plain nested dicts of jnp arrays; ``init_*`` functions build
them, ``apply`` logic lives beside. Compute runs in the config dtype with
fp32 for norms/softmax accumulations.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------- inits
def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else (1.0 / d_in) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ----------------------------------------------------------------- norms
def init_rmsnorm(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------ RoPE
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, dh]; positions: [B, S] or [S]."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)  # [dh/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, dh/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., ::2], x32[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# ------------------------------------------------------------------- MLP
def init_mlp_block(key, d: int, f: int, kind: str, dtype):
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], d, f, dtype),
            "w_up": dense_init(ks[1], d, f, dtype),
            "w_down": dense_init(ks[2], f, d, dtype),
        }
    if kind == "gelu":
        return {
            "w_up": dense_init(ks[0], d, f, dtype),
            "w_down": dense_init(ks[1], f, d, dtype),
        }
    raise ValueError(f"unknown mlp kind {kind!r}")


def mlp_block(p, x, kind: str):
    if kind == "swiglu":
        act = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif kind == "geglu":
        act = jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_up"])
    elif kind == "gelu":
        act = jax.nn.gelu(x @ p["w_up"], approximate=True)
    else:
        raise ValueError(kind)
    return act @ p["w_down"]
