from repro.models.transformer import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
)

__all__ = ["init_params", "forward", "loss_fn", "init_cache", "decode_step"]
