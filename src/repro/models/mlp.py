"""The paper's MNIST worker model.

The paper's §6 trains "an MLP on a heterogeneous version of MNIST"; we use a
784-128-10 ReLU MLP with NLL loss (the CNN of App. Table 5 is available as
``init_cnn``/``cnn_apply`` but the MLP is the benchmark default — far faster
on the CPU-only container and exhibiting the same aggregation phenomena).
"""

from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp


def init_mlp(key, sizes: Sequence[int] = (784, 128, 10)) -> Dict:
    params = {}
    keys = jax.random.split(key, len(sizes) - 1)
    for i, (d_in, d_out) in enumerate(zip(sizes[:-1], sizes[1:])):
        w_key, _ = jax.random.split(keys[i])
        params[f"w{i}"] = jax.random.normal(w_key, (d_in, d_out)) * (2.0 / d_in) ** 0.5
        params[f"b{i}"] = jnp.zeros((d_out,))
    return params


def mlp_apply(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: [B, 784] -> logits [B, 10]."""
    n_layers = len(params) // 2
    h = x
    for i in range(n_layers):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h


def nll_loss(params: Dict, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    logits = mlp_apply(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


def accuracy(params: Dict, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(mlp_apply(params, x), axis=-1) == y).astype(jnp.float32))


# ------------------------------------------------- optional CNN (Table 5)
def init_cnn(key, scale: int = 1) -> Dict:
    """CONV-CONV-(dropout)-FC-(dropout)-FC; `scale` multiplies channel widths
    (the App. A.2.3 overparameterization knob)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    c1, c2, f1 = 8 * scale, 16 * scale, 64 * scale
    return {
        "conv1": jax.random.normal(k1, (3, 3, 1, c1)) * 0.1,
        "conv2": jax.random.normal(k2, (3, 3, c1, c2)) * 0.1,
        "fc1": jax.random.normal(k3, (c2 * 49, f1)) * (1.0 / (c2 * 49)) ** 0.5,
        "b1": jnp.zeros((f1,)),
        "fc2": jax.random.normal(k4, (f1, 10)) * (1.0 / f1) ** 0.5,
        "b2": jnp.zeros((10,)),
    }


def cnn_apply(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: [B, 784] (reshaped internally to 28x28)."""
    B = x.shape[0]
    h = x.reshape(B, 28, 28, 1)
    for name in ("conv1", "conv2"):
        h = jax.lax.conv_general_dilated(
            h, params[name], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        h = jax.nn.relu(h)
        h = jax.lax.reduce_window(
            h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )
    h = h.reshape(B, -1)
    h = jax.nn.relu(h @ params["fc1"] + params["b1"])
    return h @ params["fc2"] + params["b2"]


def cnn_nll_loss(params: Dict, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    logits = cnn_apply(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))
