"""Mixture-of-Experts layer with sort-based capacity dispatch.

Design (TPU-native, expert-parallel friendly):

1. router top-k per token;
2. flatten the ``T*k`` (token, expert) assignments, argsort by expert id;
3. position-within-expert from bincount prefix sums; assignments beyond the
   per-expert capacity ``C = ceil(k*T/E * capacity_factor)`` are dropped
   (scatter ``mode="drop"``);
4. one batched einsum over the ``[E, C, D]`` buffer against stacked expert
   weights ``[E, D, F]`` — this is the MXU-shaped grouped matmul, and the
   ``E`` axis is what shards over the 'model' mesh axis (expert parallelism;
   GSPMD turns the scatter/gather into all-to-alls);
5. gather back and combine with the (renormalized) router gates.

This avoids the O(T*E*C) one-hot dispatch tensors of the classic
Shazeer-style implementation, which do not fit at the assigned shapes
(kimi-k2: T=32k/worker, E=384 => 32 GB per layer).

Aux losses: switch-style load-balance loss and router z-loss, returned for
logging/regularization.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_mlp_block, mlp_block


def init_moe(key, cfg) -> dict:
    d = cfg.d_model
    fe = cfg.d_ff_expert or cfg.d_ff
    e = cfg.n_experts
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 2 + cfg.n_shared_experts)
    n_mats = {"swiglu": 3, "geglu": 3, "gelu": 2}[cfg.mlp_kind]

    def stacked(key, d_in, d_out):
        kk = jax.random.split(key, e)
        return jnp.stack([dense_init(k, d_in, d_out, dtype) for k in kk])

    # stacked expert weights [E, D, F] / [E, F, D]
    ks_e = jax.random.split(ks[1], 3)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32, scale=0.02),
        "w_up": stacked(ks_e[0], d, fe),
        "w_down": stacked(ks_e[1], fe, d),
    }
    if n_mats == 3:
        p["w_gate"] = stacked(ks_e[2], d, fe)
    for i in range(cfg.n_shared_experts):
        p[f"shared_{i}"] = init_mlp_block(ks[2 + i], d, fe, cfg.mlp_kind, dtype)
    return p


def expert_capacity(n_tokens: int, cfg) -> int:
    c = math.ceil(cfg.experts_per_token * n_tokens / cfg.n_experts * cfg.capacity_factor)
    return max(8, min(c, n_tokens))


def moe_layer(p, x, cfg) -> Tuple[jnp.ndarray, dict]:
    """x: [B, S, D] -> (out [B, S, D], aux dict with losses)."""
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.experts_per_token
    C = expert_capacity(T, cfg)
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # [T, E]
    gates_all = jax.nn.softmax(logits, axis=-1)
    gate_topk, idx_topk = jax.lax.top_k(gates_all, K)  # [T, K]
    gate_topk = gate_topk / jnp.sum(gate_topk, axis=-1, keepdims=True)

    # ---- sort-based dispatch
    flat_e = idx_topk.reshape(-1)  # [T*K]
    flat_g = gate_topk.reshape(-1)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * K) - starts[sorted_e]
    keep = pos < C
    write_pos = jnp.where(keep, pos, C)  # OOB => dropped by scatter mode
    tok_of = order // K

    buf = jnp.zeros((E, C, D), x.dtype)
    buf = buf.at[sorted_e, write_pos].set(xt[tok_of], mode="drop")

    # ---- expert compute (grouped matmul over stacked weights)
    if "w_gate" in p:
        act_fn = jax.nn.silu if cfg.mlp_kind == "swiglu" else (
            lambda v: jax.nn.gelu(v, approximate=True)
        )
        act = act_fn(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * jnp.einsum(
            "ecd,edf->ecf", buf, p["w_up"]
        )
    else:
        act = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["w_up"]), approximate=True)
    out_buf = jnp.einsum("ecf,efd->ecd", act, p["w_down"])  # [E, C, D]

    # ---- gather + gate-combine back to tokens
    gathered = out_buf[sorted_e, jnp.minimum(write_pos, C - 1)]  # [T*K, D]
    gathered = gathered * (keep[:, None] * flat_g[order][:, None]).astype(x.dtype)
    out = jnp.zeros((T, D), x.dtype).at[tok_of].add(gathered)

    # ---- shared experts (always-on, kimi-style)
    for i in range(cfg.n_shared_experts):
        out = out + mlp_block(p[f"shared_{i}"], xt, cfg.mlp_kind)

    # ---- aux losses
    # switch load-balance: E * sum_e f_e * P_e, with f_e the fraction of
    # tokens whose TOP-1 expert is e (Switch eq. 4). Counting all top-K
    # assignments instead dilutes f_e toward 1/E — with K=E every router,
    # collapsed or balanced, would score lb_loss ≈ 1 and the loss would
    # stop penalizing collapse.
    top1 = jnp.bincount(idx_topk[:, 0], length=E)
    f_e = top1.astype(jnp.float32) / T
    p_e = jnp.mean(gates_all, axis=0)
    lb_loss = E * jnp.sum(f_e * p_e)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = 1.0 - jnp.sum(keep) / (T * K)
    aux = {
        "moe_lb_loss": lb_loss,
        "moe_z_loss": z_loss,
        "moe_drop_frac": dropped,
    }
    return out.reshape(B, S, D), aux
