"""Generic pattern-scanned decoder LM covering all assigned families.

A model is a repeating *pattern* of layers (``cfg.pattern_``), each layer a
(mixer, ff) pair with mixer in {attn, ssm} and ff in {mlp, moe, none}. The
``n_layers = period * n_periods`` stack is executed with ``lax.scan`` over
periods (params stacked on a leading period axis), which keeps HLO size and
compile time flat in depth — essential for the 61-layer dry-run configs.

Families:
  dense   pattern [(attn, mlp)]
  moe     pattern [(attn, moe)]
  ssm     pattern [(ssm, none)]
  hybrid  jamba-style period mixing attn/ssm layers and moe/mlp ffs
  vlm     dense/moe LM consuming stub patch embeddings as a prefix
  audio   musicgen: K codebook embeddings summed, K output heads

Entry points:
  init_params(cfg, key)                      -> params pytree
  forward(params, cfg, tokens, ...)          -> logits, aux
  loss_fn(params, cfg, batch)                -> scalar loss, aux
  init_cache(cfg, batch, cache_len)          -> decode cache
  decode_step(params, cfg, cache, token, pos)-> logits, new cache
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import embed_init, dense_init, init_rmsnorm, init_mlp_block, mlp_block, rmsnorm


# ================================================================== params
def _init_layer(key, mixer: str, ff: str, cfg) -> Dict[str, Any]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    p: Dict[str, Any] = {"norm1": init_rmsnorm(cfg.d_model, dtype)}
    if mixer == "attn":
        p["mixer"] = attn_mod.init_attention(k1, cfg)
    elif mixer == "ssm":
        p["mixer"] = ssm_mod.init_ssm(k1, cfg)
    else:
        raise ValueError(mixer)
    if ff != "none":
        p["norm2"] = init_rmsnorm(cfg.d_model, dtype)
        if ff == "mlp":
            p["ff"] = init_mlp_block(k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype)
        elif ff == "moe":
            p["ff"] = moe_mod.init_moe(k2, cfg)
        else:
            raise ValueError(ff)
    return p


def _init_period(key, cfg) -> Dict[str, Any]:
    keys = jax.random.split(key, len(cfg.pattern_))
    return {
        str(i): _init_layer(keys[i], mixer, ff, cfg)
        for i, (mixer, ff) in enumerate(cfg.pattern_)
    }


def init_params(cfg, key) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    params: Dict[str, Any] = {}
    n_books = max(1, cfg.n_codebooks)
    if cfg.n_codebooks:
        ks = jax.random.split(k_embed, n_books)
        params["embed"] = jnp.stack(
            [embed_init(k, cfg.vocab_size, cfg.d_model, dtype) for k in ks]
        )  # [K, V, D]
    else:
        params["embed"] = embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype)

    period_keys = jax.random.split(k_blocks, cfg.n_periods)
    params["blocks"] = jax.vmap(lambda k: _init_period(k, cfg))(period_keys)
    params["final_norm"] = init_rmsnorm(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        if cfg.n_codebooks:
            ks = jax.random.split(k_head, n_books)
            params["lm_head"] = jnp.stack(
                [dense_init(k, cfg.d_model, cfg.vocab_size, dtype) for k in ks]
            )  # [K, D, V]
        else:
            params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype)
    return params


# ================================================================== embed
def embed_tokens(params, cfg, tokens) -> jnp.ndarray:
    if cfg.n_codebooks:
        # tokens: [B, K, S]; embed: [K, V, D] -> sum over codebooks
        embs = jax.vmap(lambda be, t: jnp.take(be, t, axis=0), in_axes=(0, 1))(
            params["embed"], tokens
        )  # [K, B, S, D]
        return jnp.sum(embs, axis=0)
    return jnp.take(params["embed"], tokens, axis=0)


def unembed(params, cfg, h) -> jnp.ndarray:
    if cfg.n_codebooks:
        logits = jnp.einsum("bsd,kdv->bskv", h, params["lm_head"])
    elif cfg.tie_embeddings:
        logits = h @ params["embed"].T
    else:
        logits = h @ params["lm_head"]
    logits = logits.astype(jnp.float32)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits


# ================================================================= forward
def _layer_apply(lp, h, mixer: str, ff: str, cfg, positions, aux_acc):
    h = h + (
        attn_mod.attention(lp["mixer"], rmsnorm(lp["norm1"], h, cfg.norm_eps), cfg, positions)
        if mixer == "attn"
        else ssm_mod.ssm_layer(lp["mixer"], rmsnorm(lp["norm1"], h, cfg.norm_eps), cfg)
    )
    if ff == "mlp":
        h = h + mlp_block(lp["ff"], rmsnorm(lp["norm2"], h, cfg.norm_eps), cfg.mlp_kind)
    elif ff == "moe":
        out, aux = moe_mod.moe_layer(lp["ff"], rmsnorm(lp["norm2"], h, cfg.norm_eps), cfg)
        h = h + out
        aux_acc = {k: aux_acc.get(k, 0.0) + v for k, v in aux.items()}
    return h, aux_acc


def forward_hidden(
    params,
    cfg,
    tokens,
    prefix_embeds: Optional[jnp.ndarray] = None,
    positions: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Backbone only: final-norm hidden states [B, S, D] (token positions
    only) + aux. Callers choose which positions to unembed — the serving
    prefill unembeds just the last position, which keeps the [B, S, V] fp32
    logits tensor (e.g. 67 GB/device for gemma-7b prefill_32k) from ever
    existing."""
    h = embed_tokens(params, cfg, tokens)
    n_prefix = 0
    if prefix_embeds is not None:
        n_prefix = prefix_embeds.shape[1]
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    S = h.shape[1]
    if positions is None:
        positions = jnp.arange(S)[None, :]

    def period_body(carry, period_params):
        h, aux = carry
        for i, (mixer, ff) in enumerate(cfg.pattern_):
            h, aux = _layer_apply(period_params[str(i)], h, mixer, ff, cfg, positions, aux)
        return (h, aux), None

    if cfg.remat == "full":
        period_body = jax.checkpoint(period_body)

    aux0 = {"moe_lb_loss": jnp.zeros((), jnp.float32),
            "moe_z_loss": jnp.zeros((), jnp.float32),
            "moe_drop_frac": jnp.zeros((), jnp.float32)}
    has_moe = any(ff == "moe" for _, ff in cfg.pattern_)
    if not has_moe:
        aux0 = {}
    (h, aux), _ = jax.lax.scan(
        period_body, (h, aux0), params["blocks"], unroll=min(cfg.scan_unroll, cfg.n_periods)
    )
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if n_prefix:
        h = h[:, n_prefix:]
    return h, aux


def forward(
    params,
    cfg,
    tokens,
    prefix_embeds: Optional[jnp.ndarray] = None,
    positions: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Train forward: full-sequence logits. tokens: [B, S] ([B, K, S] for
    codebooks); prefix_embeds: [B, n_prefix, D] stub modality embeddings."""
    h, aux = forward_hidden(params, cfg, tokens, prefix_embeds, positions)
    logits = unembed(params, cfg, h)
    return logits, aux


def loss_fn(params, cfg, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token cross-entropy. batch: dict with "tokens", "labels",
    optional "prefix_embeds". labels use -100 as the ignore index."""
    logits, aux = forward(
        params, cfg, batch["tokens"], prefix_embeds=batch.get("prefix_embeds")
    )
    labels = batch["labels"]
    if cfg.n_codebooks:
        # logits [B,S,K,V], labels [B,K,S]
        labels = jnp.moveaxis(labels, 1, 2)  # [B,S,K]
    valid = labels != -100
    labels_c = jnp.clip(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels_c[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)
    if aux:
        loss = loss + cfg.router_aux_coef * aux["moe_lb_loss"] + cfg.router_z_coef * aux["moe_z_loss"]
    aux = dict(aux)
    aux["ce_loss"] = loss
    return loss, aux


# ================================================================== decode
def cache_length(cfg, seq_len: int) -> int:
    if cfg.long_context == "state":
        return 0
    if cfg.long_context == "window" and seq_len > cfg.long_context_window:
        return cfg.long_context_window
    if cfg.sliding_window:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache(cfg, batch: int, seq_len: int) -> Dict[str, Any]:
    """Stacked decode cache: one entry per pattern index, leading period axis."""
    dtype = jnp.dtype(cfg.dtype)
    L = cache_length(cfg, seq_len)
    cache: Dict[str, Any] = {}
    for i, (mixer, _) in enumerate(cfg.pattern_):
        if mixer == "attn":
            one = attn_mod.init_kv_cache(batch, max(L, 1), cfg, dtype)
        else:
            one = ssm_mod.init_ssm_cache(batch, cfg, dtype)
        cache[str(i)] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_periods,) + x.shape), one
        )
    return cache


def decode_step(params, cfg, cache, token, position) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One-token decode. token: [B] (or [B, K]); position: scalar int32.
    Returns (logits [B, V] or [B, K, V], new cache)."""
    if cfg.n_codebooks:
        # token: [B, K]; embed: [K, V, D]
        embs = jax.vmap(lambda be, t: jnp.take(be, t, axis=0), in_axes=(0, 1))(
            params["embed"], token
        )  # [K, B, D]
        h = jnp.sum(embs, axis=0)[:, None, :]
    else:
        h = jnp.take(params["embed"], token, axis=0)[:, None, :]

    def period_body(h, xs):
        period_params, period_cache = xs
        new_cache = {}
        for i, (mixer, ff) in enumerate(cfg.pattern_):
            lp = period_params[str(i)]
            x = rmsnorm(lp["norm1"], h, cfg.norm_eps)
            if mixer == "attn":
                out, nc = attn_mod.decode_attention(lp["mixer"], x, period_cache[str(i)], cfg, position)
            else:
                out, nc = ssm_mod.decode_ssm(lp["mixer"], x, period_cache[str(i)], cfg)
            new_cache[str(i)] = nc
            h = h + out
            if ff == "mlp":
                h = h + mlp_block(lp["ff"], rmsnorm(lp["norm2"], h, cfg.norm_eps), cfg.mlp_kind)
            elif ff == "moe":
                out, _ = moe_mod.moe_layer(lp["ff"], rmsnorm(lp["norm2"], h, cfg.norm_eps), cfg)
                h = h + out
        return h, new_cache

    h, new_cache = jax.lax.scan(
        period_body, h, (params["blocks"], cache), unroll=min(cfg.scan_unroll, cfg.n_periods)
    )
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = unembed(params, cfg, h)  # [B, 1, ...]
    return logits[:, 0], new_cache
