"""Packed flat-buffer robust-aggregation engine.

The per-leaf sync path (repro/distributed/robust_sync.py) pays a per-leaf
tax that dwarfs the aggregation math: every gradient leaf is resharded (an
all-to-all), upcast, and matmul'd twice per step (stats + combine), so a
transformer with hundreds of leaves issues hundreds of small collectives
and kernel launches per round. Mixing, the Gram stats phase, and the
combine are all LINEAR in the inputs, so the whole stats -> coeff ->
combine pipeline runs unchanged on one packed ``[W, N_pad]`` fp32 buffer
with exactly ONE reshard in and ONE reshard out per sync — this also covers
NNM-style pre-aggregation (Allouah et al., *Fixing by Mixing*, 2023), which
is just another row-stochastic mixing operator.

``GradPacker`` owns the layout: treedef, per-leaf shapes/dtypes, and column
offsets, computed once per tree structure and cached (``packer_for``). Each
leaf's segment is padded up to a ``block_d`` multiple. That per-leaf
alignment is what makes the packed engine BIT-IDENTICAL to the per-leaf
oracle: the Gram kernel (kernels/pairwise_gram.py) accumulates fixed
``[W, block_d]`` block dots in column order, so one call over the packed
buffer performs the exact same sequence of fp32 operations as the oracle's
chain of per-leaf calls (seeded via the kernel's ``acc`` input). Mixing and
combine reduce over the (tiny, zero-padded) worker axis per column, which
is insensitive to column blocking. Asserted in tests/test_packing.py.

COLLECTIVE SCHEDULE: ``reshard_in`` lays the packed parameter dimension
across ALL mesh axes with the worker axis replicated (one all-to-all);
every device then computes on its identical-worker ``[W, N_pad/n_dev]``
slice (partial Gram + one [W, W] all-reduce). The egress has two modes:
``reshard_out`` replicates the combined ``[N_pad]`` row (one collective)
before unpacking — right when the consumer is replicated (the single-host
simulation, the flat-stack server path); or, given ``out_shardings`` (the
params' NamedShardings from ``sharding.param_shardings``), each leaf is
sliced straight out of the still-column-sharded row and constrained to its
param's sharding, so the fully-replicated ``[N_pad]`` intermediate never
materializes — the tail collective for FSDP configs becomes per-leaf
reshards sized by what each device actually keeps. Either way the schedule
is one ingress + one egress per sync REGARDLESS of leaf count.

Kernels vs GSPMD: the Pallas kernels now run on EVERY mesh. On a trivial
mesh (absent or single-device — the single-host simulation, tests and
benchmarks) the phases call the kernels directly (``kernels/ops.py``); on a
multi-device mesh they route through ``shard_map`` wrappers
(``distributed/shard_kernels.py``) — each device runs the kernel on its
local column slice, with an explicit psum only for the Gram/norms phases —
because ``pallas_call`` is opaque to GSPMD and would otherwise not
partition. On multi-device meshes RFA and CCLIP additionally skip the
[W, W] Gram detour and run the FUSED sharded compositions
(``shard_kernels.rfa_aggregate`` / ``cclip_aggregate``): mix once in
vector space, then one local fused kernel pass + one [W]-sized psum per
iteration. ``use_kernels=False`` selects the plain ``jnp`` contractions
that GSPMD partitions across the column sharding (the numerics reference
for the shard_map path, tests/test_shard_engine.py).

The schedule invariants above are machine-checked: ``repro.analysis``
compiles packed-sync programs on the 8-device host mesh and fails CI if
the kernel route silently falls back to jnp (``jaxpr-pallas-missing``),
the replicated ``f32[n_pad]`` row reappears in a param-sharded-egress
program (``hlo-replicated-egress``), or the collective count/byte
schedule drifts past the committed budgets in ``analysis/budgets/``
(docs/static_analysis.md).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.aragg import RobustAggregator
from repro.distributed import shard_kernels
from repro.kernels import ops
from repro.telemetry import InflightMetrics, phase
from repro.telemetry import probes as _probes


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


class GradPacker:
    """Flattens a per-worker gradient pytree (leaves ``[W, ...]``) into one
    padded ``[W, n_pad]`` fp32 buffer and back. Layout is static per tree
    structure; build instances via ``packer_for`` to get caching."""

    def __init__(self, treedef, leaf_shapes: Tuple[tuple, ...],
                 leaf_dtypes: tuple, block_d: int = 2048):
        if block_d % 128:
            raise ValueError(f"block_d must be a multiple of 128, got {block_d}")
        self.treedef = treedef
        self.leaf_shapes = tuple(tuple(s) for s in leaf_shapes)  # sans worker axis
        self.leaf_dtypes = tuple(jnp.dtype(d) for d in leaf_dtypes)
        self.block_d = int(block_d)
        self.sizes = tuple(math.prod(s) for s in self.leaf_shapes)
        # each leaf segment is padded to a block_d multiple so kernel blocks
        # never straddle leaves (the bit-exactness alignment, module docstring)
        self.padded = tuple(_round_up(z, block_d) if z else 0 for z in self.sizes)
        self.offsets = tuple(
            sum(self.padded[:i]) for i in range(len(self.padded))
        )
        self.n_params = sum(self.sizes)
        self.n_pad = sum(self.padded)

    # ------------------------------------------------------------------ pack
    def pack(self, grads_w: Any) -> jnp.ndarray:
        """Stacked tree (leaves ``[W, ...]``) -> packed ``[W, n_pad]`` fp32.

        Writes each segment into a zeros buffer with dynamic_update_slice —
        under jit XLA aliases the updates in place, so pack costs one pass
        over the gradient bytes. (A concatenate of interleaved data/zero
        pieces is 20x slower on CPU XLA at transformer leaf counts.)"""
        leaves = jax.tree_util.tree_leaves(grads_w)
        W = leaves[0].shape[0]
        buf = jnp.zeros((W, self.n_pad), jnp.float32)
        for leaf, size, off in zip(leaves, self.sizes, self.offsets):
            if size == 0:
                continue
            piece = leaf.reshape(W, size).astype(jnp.float32)
            buf = jax.lax.dynamic_update_slice(buf, piece, (0, off))
        return buf

    # ---------------------------------------------------------------- unpack
    def unpack(self, vec: jnp.ndarray) -> Any:
        """Packed row ``[n_pad]`` -> gradient tree (original shapes/dtypes)."""
        leaves = [
            vec[off : off + size].reshape(shape).astype(dtype)
            for off, size, shape, dtype in zip(
                self.offsets, self.sizes, self.leaf_shapes, self.leaf_dtypes
            )
        ]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def unpack_stacked(self, buf: jnp.ndarray) -> Any:
        """Packed stack ``[k, n_pad]`` -> tree with the leading axis kept."""
        k = buf.shape[0]
        leaves = [
            buf[:, off : off + size].reshape((k,) + shape).astype(dtype)
            for off, size, shape, dtype in zip(
                self.offsets, self.sizes, self.leaf_shapes, self.leaf_dtypes
            )
        ]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"GradPacker(n_leaves={len(self.sizes)}, n_params={self.n_params}, "
                f"n_pad={self.n_pad}, block_d={self.block_d})")


_PACKER_CACHE: Dict[tuple, GradPacker] = {}


def packer_for(grads_w: Any, block_d: int = 2048) -> GradPacker:
    """Layout-cached ``GradPacker`` for this tree structure (leaves carry a
    leading worker axis that is NOT part of the layout)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads_w)
    key = (
        treedef,
        tuple(tuple(l.shape[1:]) for l in leaves),
        tuple(jnp.dtype(l.dtype) for l in leaves),
        int(block_d),
    )
    packer = _PACKER_CACHE.get(key)
    if packer is None:
        packer = GradPacker(treedef, key[1], key[2], block_d=block_d)
        _PACKER_CACHE[key] = packer
    return packer


# -------------------------------------------------------------- collectives
def reshard_in(buf: jnp.ndarray, mesh) -> jnp.ndarray:
    """The ONE ingress collective per sync: lay the packed parameter columns
    across ALL mesh axes, worker axis replicated (an all-to-all). No-op
    without a mesh (the single-host simulation path)."""
    if mesh is None:
        return buf
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = tuple(mesh.axis_names)
    return jax.lax.with_sharding_constraint(
        buf, NamedSharding(mesh, P(None, axes if len(axes) > 1 else axes[0]))
    )


def reshard_out(vec: jnp.ndarray, mesh) -> jnp.ndarray:
    """Replicated egress: one collective replicating the combined packed row
    so unpacking (and a replicated consumer) see local values. For sharded
    consumers prefer ``unpack_to_shardings`` (no replicated intermediate)."""
    if mesh is None:
        return vec
    from jax.sharding import NamedSharding, PartitionSpec as P

    return jax.lax.with_sharding_constraint(vec, NamedSharding(mesh, P()))


def unpack_to_shardings(packer: GradPacker, vec: jnp.ndarray,
                        out_shardings: Any) -> Any:
    """Param-sharded egress: slice each leaf straight out of the (still
    column-sharded) combined row and constrain it to its param's
    ``NamedSharding`` — the fully-replicated ``[n_pad]`` buffer of
    ``reshard_out`` never materializes, and GSPMD emits per-leaf reshards
    sized by what each device actually keeps (the FSDP win)."""
    shardings = jax.tree_util.tree_leaves(out_shardings)
    if len(shardings) != len(packer.sizes):
        raise ValueError(
            f"out_shardings has {len(shardings)} leaves for a "
            f"{len(packer.sizes)}-leaf layout")
    leaves = [
        jax.lax.with_sharding_constraint(
            vec[off:off + size].reshape(shape).astype(dtype), sh)
        for off, size, shape, dtype, sh in zip(
            packer.offsets, packer.sizes, packer.leaf_shapes,
            packer.leaf_dtypes, shardings)
    ]
    return jax.tree_util.tree_unflatten(packer.treedef, leaves)


def _mesh_is_trivial(mesh) -> bool:
    return mesh is None or mesh.devices.size == 1


# ------------------------------------------------------------------- engine
def packed_robust_sync(
    grads_w: Any,
    aggregator: RobustAggregator,
    key: Optional[jax.Array] = None,
    mesh=None,
    block_d: int = 2048,
    use_kernels: Optional[bool] = None,
    out_shardings: Any = None,
    telemetry: bool = False,
) -> Tuple[Any, dict]:
    """Aggregate per-worker gradient trees (leaves ``[W, ...]``) into one
    gradient tree on a single packed buffer. Returns ``(grads, info)``.

    Semantics match the per-leaf path and ``RobustAggregator`` on the
    stacked vector; with kernels on a trivial mesh, the result is
    bit-identical to the per-leaf kernel oracle (tests/test_packing.py).
    ``use_kernels=None`` resolves to the kernel route on EVERY mesh
    (shard_map-partitioned on multi-device — module docstring); pass
    ``False`` for the plain-jnp GSPMD path. ``out_shardings`` (a tree of
    ``NamedSharding`` matching ``grads_w`` sans worker axis) selects the
    param-sharded egress instead of the replicated one.

    ``telemetry=True`` adds ``info["telemetry"]`` — a device-resident
    metrics pytree (clip fractions, Weiszfeld residuals, Krum scores, trim
    masks, per-bucket dispersion, layout counters; repro/telemetry) riding
    out as ordinary outputs. With the default False the traced program is
    the SEED program: bit-exact outputs and byte-identical collective
    budgets, machine-checked by the ``sync_telemetry_off_*`` analysis
    target. The ``jax.named_scope`` phase markers are always on — they
    annotate HLO metadata only and add zero operations."""
    packer = packer_for(grads_w, block_d=block_d)
    leaves = jax.tree_util.tree_leaves(grads_w)
    W = leaves[0].shape[0]
    if packer.n_params == 0:  # degenerate all-empty tree
        return packer.unpack(jnp.zeros((packer.n_pad,), jnp.float32)), {}
    if use_kernels is None:
        use_kernels = True
    sharded = use_kernels and not _mesh_is_trivial(mesh)
    info: dict = {}
    tm = InflightMetrics(telemetry)
    if tm:
        tm.put("sync_n_workers", W)
        tm.put("sync_n_params", packer.n_params)
        tm.put("sync_n_pad", packer.n_pad)
        tm.put("sync_ingress_bytes", W * packer.n_pad * 4)
        tm.put("sync_egress_bytes",
               packer.n_params * 4
               if (out_shardings is not None and mesh is not None)
               else packer.n_pad * 4)

    def egress(out):
        with phase("unpack"):
            if out_shardings is None or mesh is None:
                return packer.unpack(reshard_out(out, mesh))
            return unpack_to_shardings(packer, out, out_shardings)

    def finish(out):
        if tm:
            info["telemetry"] = tm.tree()
        return egress(out), info

    with phase("pack"):
        buf = reshard_in(packer.pack(grads_w), mesh)  # [W, n_pad] fp32

    if aggregator.base.coordinatewise:
        mix_key = None if key is None else jax.random.split(key)[0]
        m = aggregator.mixer.matrix(mix_key, W)
        with phase("mix"):
            if not use_kernels:
                mixed = m @ buf
            else:
                mixed = (shard_kernels.mix_apply(m, buf, mesh, block_d=block_d)
                         if sharded else ops.mix_apply(m, buf, block_d=block_d))
        with phase("kernel"):
            if not use_kernels:
                out = aggregator.base.combine_leaf(mixed)
            elif aggregator.base.name == "cm":
                out = (shard_kernels.cm_aggregate(mixed, mesh, block_d=block_d)
                       if sharded else ops.cm_aggregate(mixed, block_d=block_d))
            elif aggregator.base.name == "tm":
                b = min(aggregator.base.n_trim, (mixed.shape[0] - 1) // 2)
                out = (shard_kernels.tm_aggregate(mixed, b, mesh, block_d=block_d)
                       if sharded else ops.tm_aggregate(mixed, b, block_d=block_d))
            elif sharded:  # any other combine_leaf is column-local too
                out = shard_kernels.coordinatewise_combine(
                    mixed, mesh, aggregator.base.combine_leaf)
            else:
                out = aggregator.base.combine_leaf(mixed)
        if tm:
            # probe math over the (possibly column-sharded) mixed buffer;
            # GSPMD inserts the column psums — telemetry-on programs only.
            tm.put("bucket_dispersion", lambda: _probes.bucket_dispersion(mixed))
            if aggregator.base.name == "cm":
                tm.put("cm_worker_dev", lambda: _probes.cm_worker_dev(
                    mixed, out, packer.n_params))
            elif aggregator.base.name == "tm":
                tm.put("tm_trim_frac", lambda: _probes.tm_trim_frac(
                    mixed, aggregator.base.n_trim, packer.n_params))
        return finish(out)

    if sharded and aggregator.base.name in ("rfa", "cclip"):
        # fused multi-device route: mix in vector space, then the sharded
        # Weiszfeld / fused-CCLIP composition — one local kernel pass plus
        # one [W]-sized psum per iteration instead of the [W, W] Gram
        # detour. Same math as the Gram route (weights = M^T c applied to
        # the buffer == c applied to the mixed buffer), fp32-tolerance
        # equal, asserted in tests/test_shard_engine.py. ACClip stays on
        # the Gram route (its adaptive tau needs the full norm vector).
        base = aggregator.base
        mix_key = None if key is None else jax.random.split(key)[0]
        m = aggregator.mixer.matrix(mix_key, W)
        with phase("mix"):
            mixed = shard_kernels.mix_apply(m, buf, mesh, block_d=block_d)
        with phase("kernel"):
            if base.name == "cclip":
                out = shard_kernels.cclip_aggregate(
                    mixed, base.tau, mesh, n_iters=base.n_iters, eps=base.eps,
                    block_d=block_d, with_stats=telemetry)
            else:
                out = shard_kernels.rfa_aggregate(
                    mixed, mesh, n_iters=base.n_iters, eps=base.eps,
                    block_d=block_d, with_stats=telemetry)
        if tm:
            out, stats = out
            tm.update(stats)
            tm.put("bucket_dispersion", lambda: _probes.bucket_dispersion(mixed))
        return finish(out)

    with phase("gram"):
        if not use_kernels:
            gram = buf @ buf.T
        elif sharded:
            gram = shard_kernels.gram(buf, mesh, block_d=block_d)
        else:
            gram = ops.gram(buf, block_d=block_d)
    with phase("coeff"):
        if tm:
            weights, stats = aggregator.worker_weights_and_stats_from_gram(
                gram, key=key)
            tm.update(stats)
        else:
            weights = aggregator.worker_weights_from_gram(gram, key=key)
    info["agg_weights"] = weights
    info["gram_diag_mean"] = jnp.mean(jnp.diagonal(gram))
    with phase("combine"):
        if not use_kernels:
            out = weights @ buf
        elif sharded:
            out = shard_kernels.mix_apply(weights[None, :], buf, mesh,
                                          block_d=block_d)[0]
        else:
            out = ops.mix_apply(weights[None, :], buf, block_d=block_d)[0]
    return finish(out)


def packed_aggregate(
    xs: jnp.ndarray,
    aggregator: RobustAggregator,
    key: Optional[jax.Array] = None,
    block_d: int = 2048,
    use_kernels: Optional[bool] = None,
    telemetry: bool = False,
    with_info: bool = False,
):
    """Packed engine on an already-stacked ``[W, d]`` matrix -> ``[d]``.

    The kernel-accelerated counterpart of ``RobustAggregator.__call__`` for
    callers that hold a flat stack (the cross-device FL server, benchmark
    harnesses): same mixing + rule, one pass over one padded buffer.
    ``with_info=True`` returns ``(out, info)`` — with ``telemetry=True``
    the info carries the device-resident metrics pytree."""
    out_tree, info = packed_robust_sync(
        [xs], aggregator, key=key, mesh=None, block_d=block_d,
        use_kernels=use_kernels, telemetry=telemetry,
    )
    if with_info:
        return out_tree[0], info
    return out_tree[0]
