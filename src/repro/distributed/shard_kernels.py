"""shard_map partitioning for the Pallas aggregation kernels.

``pallas_call`` is opaque to GSPMD: inside a plain jit the partitioner
cannot split a kernel across devices, so the packed engine used to fall
back to jnp contractions on any non-trivial mesh and the kernels only ever
ran in the single-host simulation. This module closes that gap with
``shard_map``: every wrapper runs the kernel on the device-local COLUMN
slice of the packed ``[W, n_pad]`` buffer (the layout ``reshard_in``
already produces — parameter columns over ALL mesh axes, worker rows
replicated), and finishes with an explicit collective only where the math
reduces over the column axis:

  gram / residual_norms / the fused-CCLIP residual output
      column reductions  -> local kernel + ``psum`` over every mesh axis;
  mix_apply / cwise_median / cwise_trimmed_mean / combine_leaf / the
      fused-CCLIP center output
      column-local       -> no collective at all; outputs STAY
      column-sharded, which is exactly what the next phase (or the
      param-sharded egress in ``packing.py``) wants.

Local column counts need not be 128-aligned — each kernel wrapper pads its
own block internally — but they must be equal across devices, so inputs are
zero-padded up to a device-count multiple first (zero columns contribute 0
to every reduction and are sliced off sharded outputs).

Numerics: the per-device block-dot order differs from the single-device
kernel schedule, so results match the trivial-mesh kernel path (and the
GSPMD jnp path) to fp32 tolerance, not bit-for-bit. Asserted against both
references in tests/test_shard_engine.py on a forced 8-device host
platform.
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels import ops


def _axes(mesh) -> tuple:
    return tuple(mesh.axis_names)


def _flat(mesh):
    ax = _axes(mesh)
    return ax if len(ax) > 1 else ax[0]


def col_spec(mesh) -> P:
    """``[W, n]`` with the column axis over ALL mesh axes (reshard_in's layout)."""
    return P(None, _flat(mesh))


def vec_spec(mesh) -> P:
    """``[n]`` laid over ALL mesh axes."""
    return P(_flat(mesh))


def _pad_cols(x: jnp.ndarray, mesh) -> Tuple[jnp.ndarray, int]:
    """Zero-pad the last axis up to a device-count multiple (shard_map needs
    equal per-device blocks). Returns ``(padded, original_n)``."""
    n_dev = int(mesh.devices.size)
    n = x.shape[-1]
    n_up = -(-n // n_dev) * n_dev
    if n_up == n:
        return x, n
    pad = [(0, 0)] * (x.ndim - 1) + [(0, n_up - n)]
    return jnp.pad(x, pad), n


# ------------------------------------------------------------------ kernels
def gram(buf: jnp.ndarray, mesh, *, block_d: int = 2048) -> jnp.ndarray:
    """Sharded stats phase: local ``[W, n/n_dev]`` Gram + psum -> ``[W, W]``."""
    ax = _axes(mesh)
    buf, _ = _pad_cols(buf, mesh)
    body = lambda b: jax.lax.psum(ops.gram(b, block_d=block_d), ax)
    return shard_map(body, mesh=mesh, in_specs=(col_spec(mesh),),
                     out_specs=P(), check_rep=False)(buf)


def mix_apply(mix: jnp.ndarray, buf: jnp.ndarray, mesh, *,
              block_d: int = 2048) -> jnp.ndarray:
    """Sharded mixing/combine: the tiny ``[m, W]`` operator is replicated and
    each device mixes its own columns — no collective; output stays
    column-sharded."""
    buf, n = _pad_cols(buf, mesh)
    body = lambda m, b: ops.mix_apply(m, b, block_d=block_d)
    out = shard_map(body, mesh=mesh, in_specs=(P(None, None), col_spec(mesh)),
                    out_specs=col_spec(mesh), check_rep=False)(mix, buf)
    return out[:, :n] if n != out.shape[1] else out


def cm_aggregate(buf: jnp.ndarray, mesh, *, block_d: int = 4096) -> jnp.ndarray:
    """Sharded coordinate-wise median: column-local selection network per
    device; output is the column-sharded ``[n]`` aggregate."""
    buf, n = _pad_cols(buf, mesh)
    body = lambda b: ops.cm_aggregate(b, block_d=block_d)
    out = shard_map(body, mesh=mesh, in_specs=(col_spec(mesh),),
                    out_specs=vec_spec(mesh), check_rep=False)(buf)
    return out[:n] if n != out.shape[0] else out


def tm_aggregate(buf: jnp.ndarray, n_trim: int, mesh, *,
                 block_d: int = 4096) -> jnp.ndarray:
    """Sharded coordinate-wise trimmed mean: column-local selection network
    per device; output is the column-sharded ``[n]`` aggregate."""
    buf, n = _pad_cols(buf, mesh)
    body = lambda b: ops.tm_aggregate(b, n_trim, block_d=block_d)
    out = shard_map(body, mesh=mesh, in_specs=(col_spec(mesh),),
                    out_specs=vec_spec(mesh), check_rep=False)(buf)
    return out[:n] if n != out.shape[0] else out


def coordinatewise_combine(buf: jnp.ndarray, mesh,
                           combine_fn: Callable) -> jnp.ndarray:
    """Any column-local ``[W, n] -> [n]`` reduction (an aggregator's
    ``combine_leaf`` — trimmed mean etc.) run per column shard."""
    buf, n = _pad_cols(buf, mesh)
    out = shard_map(combine_fn, mesh=mesh, in_specs=(col_spec(mesh),),
                    out_specs=vec_spec(mesh), check_rep=False)(buf)
    return out[:n] if n != out.shape[0] else out


def residual_norms(buf: jnp.ndarray, coeffs: Optional[jnp.ndarray] = None, *,
                   center: Optional[jnp.ndarray] = None, mesh,
                   block_d: int = 2048) -> jnp.ndarray:
    """Sharded Weiszfeld/CCLIP norms phase: local fused pass + psum -> [W].
    The center is given either as ``coeffs`` [W] (replicated; the candidate
    is formed blockwise in VMEM) or as an explicit ``center`` [d] row
    (column-sharded alongside ``buf``)."""
    if (coeffs is None) == (center is None):
        raise ValueError("provide exactly one of coeffs / center")
    ax = _axes(mesh)
    buf, _ = _pad_cols(buf, mesh)
    if coeffs is not None:
        body = lambda b, c: jax.lax.psum(ops.norms(b, c, block_d=block_d), ax)
        return shard_map(body, mesh=mesh, in_specs=(col_spec(mesh), P(None)),
                         out_specs=P(), check_rep=False)(buf, coeffs)
    center, _ = _pad_cols(center, mesh)
    body = lambda b, v: jax.lax.psum(
        ops.norms(b, center=v, block_d=block_d), ax)
    return shard_map(body, mesh=mesh,
                     in_specs=(col_spec(mesh), vec_spec(mesh)),
                     out_specs=P(), check_rep=False)(buf, center)


def cclip_fused_iter(buf: jnp.ndarray, v: jnp.ndarray, lam: jnp.ndarray,
                     mesh, *, block_d: int = 2048
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sharded fused CCLIP iteration: the center update is column-local (the
    new center stays column-sharded, one HBM pass over the local slice); the
    next-iteration residuals finish with a psum."""
    ax = _axes(mesh)
    buf, n = _pad_cols(buf, mesh)
    v, _ = _pad_cols(v, mesh)

    def body(b, vv, ll):
        v_new, r2 = ops.cclip_iter(b, vv, ll, block_d=block_d)
        return v_new, jax.lax.psum(r2, ax)

    v_new, r2 = shard_map(
        body, mesh=mesh,
        in_specs=(col_spec(mesh), vec_spec(mesh), P(None)),
        out_specs=(vec_spec(mesh), P()), check_rep=False)(buf, v, lam)
    return (v_new[:n] if n != v_new.shape[0] else v_new), r2


# ------------------------------------------------------------- compositions
def rfa_aggregate(xs: jnp.ndarray, mesh, *, n_iters: int = 8,
                  eps: float = 1e-6, block_d: int = 2048,
                  with_stats: bool = False):
    """Mesh-partitioned counterpart of ``ops.rfa_aggregate``: smoothed
    Weiszfeld with one sharded norms pass (+psum) per iteration.

    ``with_stats=True`` additionally returns the telemetry stats dict (the
    per-iteration residual norms the loop computes anyway, exported as scan
    ys). With the default False, the traced program is the seed program —
    no extra outputs, no extra collectives."""
    W = xs.shape[0]

    def body(c, _):
        r2 = residual_norms(xs, c, mesh=mesh, block_d=block_d)
        w = 1.0 / jnp.sqrt(r2 + eps**2)
        return w / jnp.sum(w), (r2 if with_stats else None)

    c0 = jnp.full((W,), 1.0 / W, jnp.float32)
    c, r2_seq = jax.lax.scan(body, c0, None, length=n_iters)
    out = mix_apply(c[None, :], xs, mesh, block_d=block_d)[0]
    if not with_stats:
        return out
    r_seq = jnp.sqrt(r2_seq + eps**2)
    stats = {
        "rfa_resid_norms": r_seq,                  # [T, W]
        "rfa_residual": jnp.sum(r_seq, axis=1),    # [T]
        "rfa_iters": n_iters,
    }
    return out, stats


def cclip_aggregate(xs: jnp.ndarray, tau: float, mesh, *, n_iters: int = 3,
                    eps: float = 1e-12, block_d: int = 2048,
                    with_stats: bool = False):
    """Mesh-partitioned counterpart of ``ops.cclip_aggregate``: one fused
    sharded pass per iteration (combine column-local, norms psum).

    ``with_stats=True`` additionally returns the telemetry stats dict (clip
    weights per iteration as scan ys). False traces the seed program."""
    W = xs.shape[0]
    v = mix_apply(jnp.full((1, W), 1.0 / W, jnp.float32), xs, mesh,
                  block_d=block_d)[0]
    r2 = residual_norms(xs, center=v, mesh=mesh, block_d=block_d)

    def body(carry, _):
        v, r2 = carry
        lam = jnp.minimum(1.0, tau / jnp.sqrt(r2 + eps))
        new_carry = cclip_fused_iter(xs, v, lam, mesh, block_d=block_d)
        return new_carry, (lam if with_stats else None)

    (v, _), lam_seq = jax.lax.scan(body, (v, r2), None, length=n_iters)
    if not with_stats:
        return v
    lam32 = lam_seq.astype(jnp.float32)
    stats = {
        "cclip_lam": lam32,                        # [T, W]
        "cclip_clip_frac": jnp.mean(
            (lam32 < 1.0).astype(jnp.float32), axis=1),
        "cclip_tau": jnp.full((n_iters,), tau, jnp.float32),
    }
    return v, stats
