"""Distributed train / prefill / decode steps (pjit + GSPMD).

Workers = data-parallel mesh groups: the global batch dim is split over the
(pod, data) axes into W worker shards; per-worker gradients come from a
``vmap`` over the worker axis (no cross-worker reduction), then the paper's
mixing + robust aggregation REPLACES the gradient all-reduce
(``robust_gradient_sync`` with the packed flat-buffer engine: one column
reshard in, one reshard out per step, regardless of how many gradient
leaves the architecture has — see repro/distributed/packing.py). Attack simulation is a feature of the
single-host simulation path (repro/training/byzantine.py); the distributed
path runs the defense.

Momentum modes (DESIGN.md §5):
  worker : Algorithm 2 — per-worker momentum leaves [W, ...] (small/mid archs)
  server : Remark 7 — raw per-worker grads robust-aggregated, momentum in
           the (shardable) optimizer state (giant archs / FSDP).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ByzConfig, InputShape, ModelConfig
from repro.distributed.robust_sync import robust_gradient_sync
from repro.distributed.sharding import (
    batch_spec,
    cache_shardings,
    constrain_worker_tree,
    overrides_from_config,
    param_shardings,
    worker_grad_spec,
)
from repro.launch.mesh import n_workers as mesh_n_workers
from repro.models import transformer as tfm
from repro.optim import make_optimizer


# ------------------------------------------------------------- input specs
def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.n_codebooks:
            specs = {
                "tokens": jax.ShapeDtypeStruct((B, cfg.n_codebooks, S), i32),
                "labels": jax.ShapeDtypeStruct((B, cfg.n_codebooks, S), i32),
            }
        else:
            specs = {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        if cfg.n_prefix_tokens:
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_prefix_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        return specs
    # decode: ONE new token against a seq_len cache
    tok_shape = (B, cfg.n_codebooks) if cfg.n_codebooks else (B,)
    return {"token": jax.ShapeDtypeStruct(tok_shape, i32)}


def batch_shardings(cfg: ModelConfig, shape: InputShape, mesh) -> Dict[str, NamedSharding]:
    bs = batch_spec(mesh)
    specs = input_specs(cfg, shape)
    out = {}
    for k, v in specs.items():
        spec = [None] * len(v.shape)
        nw = mesh_n_workers(mesh)
        if v.shape[0] % nw == 0 and v.shape[0] >= nw:
            spec[0] = bs[0]
        out[k] = NamedSharding(mesh, P(*spec))
    return out


# -------------------------------------------------------------- train step
def make_train_step(
    cfg: ModelConfig,
    byz: ByzConfig,
    mesh,
    lr: float = 1e-3,
    optimizer: str = "sgdm",
    telemetry: bool = False,
) -> Tuple[Callable, Dict[str, Any]]:
    """Returns (step_fn, shardings) where
    step_fn(params, opt_state, worker_m, key, batch) ->
        (params, opt_state, worker_m, metrics).
    ``worker_m`` is a zeros-like stacked tree for momentum_mode=worker, else
    an empty dict. ``shardings`` maps each argument to NamedShardings.

    ``telemetry=True`` adds the sync's device-resident metrics pytree as
    ``metrics["telemetry"]`` (repro/telemetry). The flag is baked into the
    closure, so the step's signature and jit cache are unaffected; with the
    default False the traced program is the seed program.
    """
    W = mesh_n_workers(mesh)
    aggregator = byz.make_aggregator(W)
    opt_init, opt_update = make_optimizer(
        optimizer, lr=lr, beta1=byz.worker_momentum or 0.9,
        m_dtype=cfg.opt_m_dtype,
    )
    use_worker_momentum = cfg.momentum_mode == "worker" and byz.worker_momentum > 0
    is_plain_mean = byz.aggregator in ("mean", "avg") and byz.mixing in ("none", "")

    # Param shardings are needed INSIDE step_fn: for FSDP configs the packed
    # engine's egress unpacks the aggregate directly to each param's
    # NamedSharding instead of materializing a replicated [n_pad] row on
    # every device. Non-FSDP params are (near-)replicated, where per-leaf
    # unpacking just splits the one egress all-gather into many — keep the
    # replicated reshard_out there.
    params_shape = jax.eval_shape(lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))
    params_sh = param_shardings(params_shape, mesh, fsdp=cfg.fsdp,
                                overrides=overrides_from_config(cfg))
    egress_sh = params_sh if cfg.fsdp else None

    def loss_of(params, b):
        return tfm.loss_fn(params, cfg, b)

    def step_fn(params, opt_state, worker_m, key, batch):
        # [B_global, ...] -> [W, b_local, ...]
        def split_workers(x):
            return x.reshape((W, x.shape[0] // W) + x.shape[1:])

        wbatch = jax.tree_util.tree_map(split_workers, batch)

        if is_plain_mean and not use_worker_momentum:
            # BASELINE: standard data-parallel mean gradient (the paper's Avg).
            def mean_loss(p):
                loss, aux = jax.vmap(lambda b: loss_of(p, b))(wbatch)
                return jnp.mean(loss), aux

            (loss, aux), grads = jax.value_and_grad(mean_loss, has_aux=True)(params)
            agg_grads = grads
            info = {}
        else:
            def one_worker(b):
                (loss, aux), g = jax.value_and_grad(loss_of, has_aux=True)(params, b)
                return g, loss

            grads_w, losses = jax.vmap(one_worker)(wbatch)
            loss = jnp.mean(losses)
            if use_worker_momentum:
                beta = byz.worker_momentum
                worker_m = jax.tree_util.tree_map(
                    lambda m, g: beta * m + (1.0 - beta) * g.astype(jnp.float32),
                    worker_m,
                    grads_w,
                )
                messages = worker_m
            else:
                messages = grads_w
            agg_grads, info = robust_gradient_sync(
                messages, aggregator, key=key, mesh=mesh, engine="packed",
                out_shardings=egress_sh, telemetry=telemetry,
            )

        params, opt_state = opt_update(agg_grads, opt_state, params)
        metrics = {"loss": loss}
        if telemetry and "telemetry" in info:
            metrics["telemetry"] = info["telemetry"]
        return params, opt_state, worker_m, metrics

    # ----- shardings (params_sh computed above, before step_fn)
    opt_shape = jax.eval_shape(opt_init, params_shape)
    # optimizer moments mirror param shardings; step counter replicated
    opt_sh = _opt_state_shardings(opt_shape, params_sh, mesh)
    if use_worker_momentum:
        wm_shape = jax.eval_shape(
            lambda p: jax.tree_util.tree_map(
                lambda x: jnp.zeros((W,) + x.shape, jnp.float32), p
            ),
            params_shape,
        )
        wm_sh = jax.tree_util.tree_map(lambda sh: worker_grad_spec(sh, mesh), params_sh)
    else:
        wm_shape, wm_sh = {}, {}

    shardings = {
        "params": params_sh,
        "opt_state": opt_sh,
        "worker_m": wm_sh,
        "params_shape": params_shape,
        "opt_shape": opt_shape,
        "wm_shape": wm_shape,
        "replicated": NamedSharding(mesh, P()),
    }
    return step_fn, shardings


def _opt_state_shardings(opt_shape, params_sh, mesh):
    """OptState(step, m, v): moments mirror params; step replicated."""
    rep = NamedSharding(mesh, P())

    def mirror(tree):
        if tree is None:
            return None
        return jax.tree_util.tree_map(lambda _, sh: sh, tree, params_sh)

    return type(opt_shape)(step=rep, m=mirror(opt_shape.m), v=mirror(opt_shape.v))


# ------------------------------------------------------------ prefill step
def make_prefill_step(cfg: ModelConfig, mesh, last_only: bool = True) -> Callable:
    """Serving prefill. ``last_only`` (default) unembeds ONLY the final
    position — the next-token logits a server actually needs. Materializing
    full-sequence fp32 logits is a [B, S, V] tensor (67 GB/device for
    gemma-7b at prefill_32k) that dominated peak memory; see EXPERIMENTS.md
    §Perf iteration 2."""

    def prefill(params, batch):
        h, _ = tfm.forward_hidden(
            params, cfg, batch["tokens"], prefix_embeds=batch.get("prefix_embeds")
        )
        if last_only:
            h = h[:, -1:]
        return tfm.unembed(params, cfg, h)

    return prefill


# ------------------------------------------------------------- decode step
def make_serve_step(cfg: ModelConfig, mesh, shape: InputShape) -> Tuple[Callable, Any, Any]:
    """Returns (serve_fn(params, cache, token, position) -> (logits, cache),
    cache_shape (ShapeDtypeStructs), cache_sharding)."""
    B = shape.global_batch

    def serve(params, cache, token, position):
        return tfm.decode_step(params, cfg, cache, token, position)

    cache_shape = jax.eval_shape(lambda: tfm.init_cache(cfg, B, shape.seq_len))
    cache_sh = cache_shardings(cache_shape, mesh, B)
    return serve, cache_shape, cache_sh
