"""Sharding rule engine.

Generic per-leaf rules instead of a hand-written table per architecture:

- tensor ("model") axis: the largest dim divisible by the model-axis size
  (prefers the last dims — the d_ff / head / expert-shaped ones);
- optional FSDP: among remaining dims, the largest one divisible by the
  combined (pod, data) size — or just data — is sharded over those axes
  (params, grads and optimizer state all follow the same spec);
- leaves under "blocks" carry a leading period axis (lax.scan stacking)
  which is never sharded;
- decode caches get dedicated rules (batch over workers; for batch-1 long
  contexts the cache length shards over the data axis = sequence
  parallelism for the KV cache).

Per-arch overrides (the §Perf hillclimb lever) can replace the inferred
spec via ``overrides={path_regex: PartitionSpec}``.
"""

from __future__ import annotations

import re
from typing import Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _path_str(path) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path
    )


def _pick_dim(shape, size: int, taken: set, start: int = 0) -> Optional[int]:
    """Largest dim (index >= start, not taken) divisible by ``size``."""
    best, best_dim = -1, None
    for i in range(start, len(shape)):
        if i in taken:
            continue
        if shape[i] % size == 0 and shape[i] >= size and shape[i] > best:
            best, best_dim = shape[i], i
    return best_dim


def infer_param_spec(
    path_str: str,
    shape,
    mesh: Mesh,
    fsdp: bool = False,
) -> P:
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_size = axes.get("model", 1)
    start = 1 if path_str.startswith("blocks") and len(shape) > 1 else 0
    spec = [None] * len(shape)
    taken: set = set()

    m_dim = _pick_dim(shape, model_size, taken, start)
    if m_dim is not None and model_size > 1:
        spec[m_dim] = "model"
        taken.add(m_dim)

    if fsdp:
        worker_axes = tuple(a for a in ("pod", "data") if a in axes)
        combined = int(np.prod([axes[a] for a in worker_axes])) if worker_axes else 1
        f_dim = _pick_dim(shape, combined, taken, start)
        if f_dim is not None and combined > 1:
            spec[f_dim] = worker_axes if len(worker_axes) > 1 else worker_axes[0]
            taken.add(f_dim)
        elif "data" in axes:  # fall back to data-only FSDP
            f_dim = _pick_dim(shape, axes["data"], taken, start)
            if f_dim is not None and axes["data"] > 1:
                spec[f_dim] = "data"
    return P(*spec)


def overrides_from_config(cfg) -> Dict[str, P]:
    """Decode ``ModelConfig.sharding_overrides`` — hashable nested tuples
    ``((path_regex, spec_entries), ...)`` — into the ``{regex:
    PartitionSpec}`` mapping ``param_shardings`` consumes. Each spec entry
    is a mesh-axis name, a tuple of axis names, or None."""
    return {
        pat: P(*(tuple(e) if isinstance(e, (tuple, list)) else e
                 for e in entries))
        for pat, entries in getattr(cfg, "sharding_overrides", ()) or ()
    }


def param_shardings(params, mesh: Mesh, fsdp: bool = False, overrides: Optional[Dict[str, P]] = None):
    """NamedSharding pytree matching ``params`` (works on ShapeDtypeStructs)."""
    overrides = overrides or {}

    def one(path, leaf):
        ps = _path_str(path)
        for pat, spec in overrides.items():
            if re.search(pat, ps):
                return NamedSharding(mesh, spec)
        return NamedSharding(mesh, infer_param_spec(ps, leaf.shape, mesh, fsdp))

    return jax.tree_util.tree_map_with_path(one, params)


def batch_spec(mesh: Mesh) -> P:
    """Global batch dim over all worker axes."""
    w = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(w if len(w) > 1 else (w[0] if w else None))


def worker_grad_spec(param_sharding: NamedSharding, mesh: Mesh) -> NamedSharding:
    """Sharding for a [W, ...]-stacked gradient leaf: worker axes on dim 0,
    the param's 'model' placements kept, its FSDP placements dropped."""
    from repro.launch.mesh import worker_axes

    w = worker_axes(mesh)
    base = param_sharding.spec
    kept = tuple(s if s == "model" else None for s in base)
    return NamedSharding(mesh, P(w if len(w) > 1 else w[0], *kept))


def constrain_worker_tree(tree, params_sh, mesh: Mesh):
    """Constrain each [W, ...] leaf of ``tree`` to its worker-stacked spec."""
    return jax.tree_util.tree_map(
        lambda leaf, sh: jax.lax.with_sharding_constraint(
            leaf, worker_grad_spec(sh, mesh)),
        tree,
        params_sh,
    )


def cache_shardings(cache, mesh: Mesh, batch: int):
    """Decode-cache shardings. Leaves: [period, B, L, KV, dh] (attn k/v),
    [period, B, K-1, C] (conv), [period, B, H, P, N] (ssm state)."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    worker_axes = tuple(a for a in ("pod", "data") if a in axes)
    n_work = int(np.prod([axes[a] for a in worker_axes]))
    model_size = axes.get("model", 1)

    def one(path, leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        # dim 0 = period axis (never sharded); dim 1 = batch
        if batch % n_work == 0 and batch >= n_work:
            spec[1] = worker_axes if len(worker_axes) > 1 else worker_axes[0]
            # shard heads/channels over model where divisible
            d = _pick_dim(shape, model_size, {0, 1}, 2)
            if d is not None:
                spec[d] = "model"
        else:
            # batch-1 long-context: sequence-shard the cache over data,
            # heads over model where divisible.
            ps = _path_str(path)
            if ("k" in ps.split("/")[-1] or "v" in ps.split("/")[-1]) and len(shape) == 5:
                if shape[2] % axes.get("data", 1) == 0:
                    spec[2] = "data"
                if shape[3] % model_size == 0 and shape[3] >= model_size:
                    spec[3] = "model"
            else:
                d = _pick_dim(shape, model_size, {0, 1}, 2)
                if d is not None:
                    spec[d] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache)
