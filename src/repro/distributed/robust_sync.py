"""Byzantine-robust gradient synchronization — the paper's technique as the
distributed gradient sync (replaces the mean all-reduce across workers).

Factorized Gram-space implementation (DESIGN.md §4): the stacked
``[n_workers, n_params]`` matrix never exists. Per gradient leaf (with a
leading worker axis, sharded over the (pod, data) mesh axes):

  stats phase   : Gram matrix G += einsum('w...,v...->wv', leaf, leaf)
                  accumulated over leaves; the result is a tiny [W, W]
                  replicated array.
  coeff phase   : mixing (bucketing/resampling) composes linearly
                  (G_y = M G M^T) and Krum/RFA/CCLIP run in coefficient
                  space — O(W^2) work on the [W, W] matrix.
  combine phase : out_leaf = einsum('w,w...->...', M^T c, leaf).

Coordinatewise rules (CM / trimmed mean) skip the stats phase: mixing is
applied per leaf (tiny matmul over the worker axis) and the median runs
leaf-locally — exactly equal to the stacked semantics.

COLLECTIVE SCHEDULE (the systems-critical part, EXPERIMENTS.md §Perf):
naively, the worker axis of a leaf lives on the (pod, data) mesh axes, so
GSPMD resolves the cross-worker contractions by ALL-GATHERING the full
fp32 ``[W, N]`` stack onto every device — W x params x 4 bytes of ICI
traffic (74 GB/chip/step for tinyllama, 70 TB for kimi-k2). We instead
force a COLUMN resharding first (``_colshard``): an all-to-all that lays
the flattened parameter dimension across ALL mesh axes with the worker
axis replicated. Each device then holds an identical-worker slice
[W, N/n_devices], computes its partial Gram locally, and a [W, W]
all-reduce finishes the stats phase. Traffic per leaf ~= 2x leaf bytes
(all-to-all there, reshard back after combine) instead of W x leaf bytes.

Semantics are bit-identical to ``RobustAggregator(...)`` on the stacked
vector (verified in tests/test_robust_sync.py) — sharding constraints
never change values.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.aragg import RobustAggregator


def _leaf32(x):
    return x.astype(jnp.float32)


def _colshard(flat: jnp.ndarray, mesh) -> jnp.ndarray:
    """Reshard a [W, N_leaf] stack: worker axis replicated, N over ALL mesh
    axes (an all-to-all; see module docstring). No-op without a mesh (the
    single-host simulation path)."""
    if mesh is None:
        return flat
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = tuple(mesh.axis_names)
    return jax.lax.with_sharding_constraint(
        flat, NamedSharding(mesh, P(None, axes if len(axes) > 1 else axes[0]))
    )


def tree_gram(grads_w: Any, n_workers: int, mesh=None) -> jnp.ndarray:
    """Sum over leaves of per-leaf worker Gram matrices -> [W, W] fp32."""
    gram = jnp.zeros((n_workers, n_workers), jnp.float32)
    for leaf in jax.tree_util.tree_leaves(grads_w):
        flat = _colshard(leaf.reshape(n_workers, -1), mesh)
        flat = _leaf32(flat)
        gram = gram + flat @ flat.T
    return gram


def tree_combine(grads_w: Any, weights: jnp.ndarray, mesh=None) -> Any:
    """Per-leaf weighted combination over the worker axis."""
    def one(leaf):
        flat = _colshard(leaf.reshape(leaf.shape[0], -1), mesh)
        out = weights @ _leaf32(flat)
        return out.reshape(leaf.shape[1:]).astype(leaf.dtype)

    return jax.tree_util.tree_map(one, grads_w)


def tree_mix(grads_w: Any, mix_matrix: jnp.ndarray, mesh=None) -> Any:
    """Apply the mixing operator leaf-wise: [W, ...] -> [m, ...]."""
    def one(leaf):
        flat = _colshard(leaf.reshape(leaf.shape[0], -1), mesh)
        out = mix_matrix @ _leaf32(flat)
        return out.reshape((mix_matrix.shape[0],) + leaf.shape[1:]).astype(leaf.dtype)

    return jax.tree_util.tree_map(one, grads_w)


def robust_gradient_sync(
    grads_w: Any,
    aggregator: RobustAggregator,
    key: Optional[jax.Array] = None,
    mesh=None,
) -> Tuple[Any, dict]:
    """Aggregate per-worker gradient trees (leaves ``[W, ...]``) into one
    gradient tree, using mixing + the robust rule. Returns (grads, info)."""
    leaves = jax.tree_util.tree_leaves(grads_w)
    n_workers = leaves[0].shape[0]
    info = {}

    if aggregator.base.coordinatewise:
        mix_key = None if key is None else jax.random.split(key)[0]
        m = aggregator.mixer.matrix(mix_key, n_workers)
        mixed = tree_mix(grads_w, m, mesh=mesh)
        out = jax.tree_util.tree_map(
            lambda leaf: aggregator.base.combine_leaf(leaf), mixed
        )
        return out, info

    gram = tree_gram(grads_w, n_workers, mesh=mesh)
    weights = aggregator.worker_weights_from_gram(gram, key=key)
    info["agg_weights"] = weights
    info["gram_diag_mean"] = jnp.mean(jnp.diagonal(gram))
    return tree_combine(grads_w, weights, mesh=mesh), info
