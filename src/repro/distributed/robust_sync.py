"""Byzantine-robust gradient synchronization — the paper's technique as the
distributed gradient sync (replaces the mean all-reduce across workers).

Factorized Gram-space implementation (DESIGN.md §4): the stacked
``[n_workers, n_params]`` matrix never exists as a per-worker gather. The
three phases, all linear in the inputs:

  stats phase   : Gram matrix G = einsum('wn,vn->wv', X, X) — a tiny
                  [W, W] replicated array.
  coeff phase   : mixing (bucketing/resampling) composes linearly
                  (G_y = M G M^T) and Krum/RFA/CCLIP run in coefficient
                  space — O(W^2) work on the [W, W] matrix.
  combine phase : out = einsum('w,wn->n', M^T c, X).

Coordinatewise rules (CM / trimmed mean) skip the stats phase: mixing is a
tiny matmul over the worker axis and the median runs column-locally —
exactly equal to the stacked semantics.

COLLECTIVE SCHEDULE (the systems-critical part, EXPERIMENTS.md §Perf):
naively, the worker axis of a leaf lives on the (pod, data) mesh axes, so
GSPMD resolves the cross-worker contractions by ALL-GATHERING the full
fp32 ``[W, N]`` stack onto every device — W x params x 4 bytes of ICI
traffic (74 GB/chip/step for tinyllama, 70 TB for kimi-k2). Both engines
here instead force a COLUMN resharding first: an all-to-all that lays the
flattened parameter dimension across ALL mesh axes with the worker axis
replicated, so each device holds an identical-worker column slice, computes
its partial Gram locally, and a [W, W] all-reduce finishes the stats phase.

PACKED SCHEDULE (default, ``engine="packed"``): the whole gradient pytree
is flattened ONCE into a padded ``[W, N_pad]`` fp32 buffer (layout cached
per tree structure — repro/distributed/packing.py), column-resharded ONCE,
run through the Pallas kernels (pairwise_gram / bucket_mix / cwise_median)
on the packed buffer — shard_map-partitioned on multi-device meshes, each
device running the kernel on its local column slice with an explicit psum
for the Gram phase (repro/distributed/shard_kernels.py) — then egressed
ONCE: either a replicated reshard-out, or, with ``out_shardings``, a
param-sharded unpack that never materializes the replicated ``[N_pad]``
row (the FSDP egress). One ingress + one egress and one kernel launch per
phase PER SYNC, regardless of leaf count. Traffic ~= 2x gradient bytes.

PER-LEAF SCHEDULE (``engine="per_leaf"``, this module): the legacy
fallback, kept as the bit-exactness oracle for the packed engine. Each leaf
is resharded, upcast, and contracted separately: the same 2x-bytes traffic
total, but split into TWO collectives and several kernel launches PER LEAF
per step (stats + combine) — hundreds of small all-to-alls per round on a
transformer, which is what the packed engine eliminates. With
``use_kernels=True`` its Gram phase chains through the same Pallas kernel
blocks as the packed engine (``acc`` + ``full_blocks``), making the two
engines bit-identical (asserted in tests/test_packing.py); with the default
``use_kernels=False`` it is the pure-jnp GSPMD path.

Semantics are equal to ``RobustAggregator(...)`` on the stacked vector
(verified in tests/test_robust_sync.py) — sharding constraints never change
values. The collective schedule itself (one ingress + one egress, kernel
route taken, no replicated egress row) is regression-gated by
``python -m repro.analysis``, which compiles this sync on the 8-device
host mesh and checks it against committed per-target collective budgets
(docs/static_analysis.md).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.aragg import RobustAggregator
from repro.distributed import packing
from repro.kernels import ops


def _leaf32(x):
    return x.astype(jnp.float32)


def _colshard(flat: jnp.ndarray, mesh) -> jnp.ndarray:
    """Reshard a [W, N_leaf] stack: worker axis replicated, N over ALL mesh
    axes (an all-to-all; see module docstring). No-op without a mesh (the
    single-host simulation path)."""
    if mesh is None:
        return flat
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = tuple(mesh.axis_names)
    return jax.lax.with_sharding_constraint(
        flat, NamedSharding(mesh, P(None, axes if len(axes) > 1 else axes[0]))
    )


def tree_gram(grads_w: Any, n_workers: int, mesh=None, use_kernels: bool = False,
              block_d: int = 2048) -> jnp.ndarray:
    """Sum over leaves of per-leaf worker Gram matrices -> [W, W] fp32.

    With ``use_kernels`` the per-leaf contributions chain through the Pallas
    Gram kernel with fixed ``block_d`` blocks and a carried accumulator —
    the exact block-dot sequence of the packed engine (bit-exactness)."""
    gram = jnp.zeros((n_workers, n_workers), jnp.float32)
    for leaf in jax.tree_util.tree_leaves(grads_w):
        if leaf.size == 0:
            continue
        flat = _colshard(leaf.reshape(n_workers, -1), mesh)
        if use_kernels:
            gram = ops.gram(flat, acc=gram, block_d=block_d, full_blocks=True)
        else:
            flat = _leaf32(flat)
            gram = gram + flat @ flat.T
    return gram


def tree_combine(grads_w: Any, weights: jnp.ndarray, mesh=None,
                 use_kernels: bool = False, block_d: int = 2048) -> Any:
    """Per-leaf weighted combination over the worker axis."""
    def one(leaf):
        if leaf.size == 0:  # guard BEFORE reshape(W, -1) / reshard
            return jnp.zeros(leaf.shape[1:], leaf.dtype)
        flat = _colshard(leaf.reshape(leaf.shape[0], -1), mesh)
        if use_kernels:
            out = ops.mix_apply(weights[None, :], flat, block_d=block_d)[0]
        else:
            out = weights @ _leaf32(flat)
        return out.reshape(leaf.shape[1:]).astype(leaf.dtype)

    return jax.tree_util.tree_map(one, grads_w)


def tree_mix(grads_w: Any, mix_matrix: jnp.ndarray, mesh=None,
             use_kernels: bool = False, block_d: int = 2048) -> Any:
    """Apply the mixing operator leaf-wise: [W, ...] -> [m, ...]."""
    def one(leaf):
        if leaf.size == 0:  # guard BEFORE reshape(W, -1) / reshard
            return jnp.zeros((mix_matrix.shape[0],) + leaf.shape[1:], leaf.dtype)
        flat = _colshard(leaf.reshape(leaf.shape[0], -1), mesh)
        if use_kernels:
            out = ops.mix_apply(mix_matrix, flat, block_d=block_d)
        else:
            out = mix_matrix @ _leaf32(flat)
        return out.reshape((mix_matrix.shape[0],) + leaf.shape[1:]).astype(leaf.dtype)

    return jax.tree_util.tree_map(one, grads_w)


def _per_leaf_sync(
    grads_w: Any,
    aggregator: RobustAggregator,
    key: Optional[jax.Array],
    mesh,
    use_kernels: bool,
    block_d: int,
    telemetry: bool = False,
) -> Tuple[Any, dict]:
    """The per-leaf fallback engine (two collectives per leaf; docstring).

    ``telemetry=True`` adds ``info["telemetry"]`` from the Gram-space probes
    (non-coordinatewise rules only — the coordinatewise route has no stacked
    buffer to probe without materializing one; use the packed engine for
    CM/TM telemetry)."""
    leaves = jax.tree_util.tree_leaves(grads_w)
    n_workers = leaves[0].shape[0]
    info: dict = {}

    if aggregator.base.coordinatewise:
        mix_key = None if key is None else jax.random.split(key)[0]
        m = aggregator.mixer.matrix(mix_key, n_workers)
        if not use_kernels:
            mixed = tree_mix(grads_w, m, mesh=mesh)
            out = jax.tree_util.tree_map(
                lambda leaf: aggregator.base.combine_leaf(leaf), mixed
            )
            return out, info

        # kernel route: fp32 end-to-end per leaf, CM through the median
        # kernel — mirrors the packed engine phase for phase.
        def one(leaf):
            if leaf.size == 0:  # guard BEFORE reshape(W, -1) / reshard
                return jnp.zeros(leaf.shape[1:], leaf.dtype)
            flat = _colshard(leaf.reshape(n_workers, -1), mesh)
            mixed = ops.mix_apply(m, flat, block_d=block_d)
            if aggregator.base.name == "cm":
                out = ops.cm_aggregate(mixed, block_d=block_d)
            elif aggregator.base.name == "tm":
                b = min(aggregator.base.n_trim, (mixed.shape[0] - 1) // 2)
                out = ops.tm_aggregate(mixed, b, block_d=block_d)
            else:
                out = aggregator.base.combine_leaf(mixed)
            return out.reshape(leaf.shape[1:]).astype(leaf.dtype)

        return jax.tree_util.tree_map(one, grads_w), info

    gram = tree_gram(grads_w, n_workers, mesh=mesh, use_kernels=use_kernels,
                     block_d=block_d)
    if telemetry:
        weights, stats = aggregator.worker_weights_and_stats_from_gram(
            gram, key=key)
        info["telemetry"] = stats
    else:
        weights = aggregator.worker_weights_from_gram(gram, key=key)
    info["agg_weights"] = weights
    info["gram_diag_mean"] = jnp.mean(jnp.diagonal(gram))
    combined = tree_combine(grads_w, weights, mesh=mesh,
                            use_kernels=use_kernels, block_d=block_d)
    return combined, info


def robust_gradient_sync(
    grads_w: Any,
    aggregator: RobustAggregator,
    key: Optional[jax.Array] = None,
    mesh=None,
    engine: str = "packed",
    block_d: int = 2048,
    use_kernels: Optional[bool] = None,
    out_shardings: Any = None,
    telemetry: bool = False,
) -> Tuple[Any, dict]:
    """Aggregate per-worker gradient trees (leaves ``[W, ...]``) into one
    gradient tree, using mixing + the robust rule. Returns (grads, info).

    ``engine="packed"`` (default) runs the single-buffer engine
    (repro/distributed/packing.py); ``engine="per_leaf"`` is the legacy
    fallback and bit-exactness oracle. ``use_kernels=None`` resolves to the
    Pallas route on every mesh for the packed engine (shard_map-partitioned
    on multi-device), and to pure jnp for the per-leaf engine.
    ``out_shardings`` (NamedSharding tree matching the gradients sans
    worker axis) selects the param-sharded egress. ``telemetry=True`` adds
    the device-resident metrics pytree as ``info["telemetry"]``; the
    default False traces the seed program exactly (repro/telemetry)."""
    if engine == "packed":
        return packing.packed_robust_sync(
            grads_w, aggregator, key=key, mesh=mesh, block_d=block_d,
            use_kernels=use_kernels, out_shardings=out_shardings,
            telemetry=telemetry,
        )
    if engine != "per_leaf":
        raise ValueError(f"unknown sync engine {engine!r}")
    out, info = _per_leaf_sync(grads_w, aggregator, key, mesh,
                               bool(use_kernels), block_d,
                               telemetry=telemetry)
    if out_shardings is not None and mesh is not None:
        out = jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, out, out_shardings)
    return out, info
