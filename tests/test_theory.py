"""Executable theory: Lemma 1 estimators, Theorem III lower bound, Thm IV gate."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.theory import (
    LowerBoundInstance,
    heterogeneity_zeta_sq,
    overparam_bound_ok,
    pairwise_variance,
)


def test_pairwise_variance_matches_naive(key):
    xs = jax.random.normal(key, (9, 13))
    n = xs.shape[0]
    acc = 0.0
    for i in range(n):
        for j in range(n):
            if i != j:
                acc += float(jnp.sum((xs[i] - xs[j]) ** 2))
    naive = acc / (n * (n - 1))
    np.testing.assert_allclose(float(pairwise_variance(xs)), naive, rtol=1e-4)


def test_zeta_sq_zero_for_identical(key):
    x = jax.random.normal(key, (6,))
    xs = jnp.broadcast_to(x, (5, 6))
    assert float(heterogeneity_zeta_sq(xs)) < 1e-10


def test_lower_bound_instance_indistinguishable():
    """The two worlds report IDENTICAL gradients — the crux of Theorem III."""
    inst = LowerBoundInstance(n=10, delta=0.2, zeta=1.0, mu=1.0)
    x = jnp.asarray(0.7)
    for i in range(inst.n):
        g = inst.worker_grad(i, x)
        # the same function set in both worlds: world assignment changes only
        # which workers count as good, not what they send.
        assert jnp.isfinite(g)
    assert inst.optimum(1) != inst.optimum(2)


def test_lower_bound_floor_matches_paper_constant():
    inst = LowerBoundInstance(n=10, delta=0.2, zeta=2.0, mu=0.5)
    # Omega(delta zeta^2 / mu): paper constant 1/4
    assert np.isclose(inst.suboptimality_floor(), 0.2 * 4.0 / (4 * 0.5))


def test_minimax_point_achieves_floor():
    """The midpoint output achieves the Omega(delta zeta^2 / mu) rate (with
    the exact minimax constant 1/8 = half of the paper's stated 1/4 bound),
    and no constant output does better on BOTH worlds."""
    inst = LowerBoundInstance(n=20, delta=0.1, zeta=1.0, mu=1.0)
    x_star, err = inst.best_achievable_max_error()
    np.testing.assert_allclose(err, inst.suboptimality_floor() / 2, rtol=1e-6)
    # any other candidate has worse max-error
    for cand in [0.0, inst.optimum(1), 0.9 * x_star, 1.1 * x_star]:
        worst = max(
            float(inst.objective(w, jnp.asarray(cand)) - inst.objective(
                w, jnp.asarray(inst.optimum(w))))
            for w in (1, 2)
        )
        assert worst >= err - 1e-9


def test_overparam_gate():
    assert overparam_bound_ok(c=1.0, delta=0.0, B_sq=100.0)
    assert overparam_bound_ok(c=1.0, delta=0.1, B_sq=3.0)
    assert not overparam_bound_ok(c=10.0, delta=0.1, B_sq=1.0)
