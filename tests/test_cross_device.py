"""Cross-device FL mode (paper Remark 7): history-less clients + server
momentum + agnostic robust aggregation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ByzConfig
from repro.data.partition import worker_datasets
from repro.data.synthetic import make_train_test
from repro.models.mlp import accuracy, init_mlp, nll_loss
from repro.training.cross_device import CrossDeviceSim


@pytest.fixture(scope="module")
def pool():
    key = jax.random.PRNGKey(0)
    X, Y, Xt, Yt = make_train_test(key, n_train=3000, n_test=500)
    # 50-client pool, 10% Byzantine, non-iid shards
    wx, wy = worker_datasets(X, Y, n_good=45, n_byz=5, noniid=True)
    return jnp.asarray(wx), jnp.asarray(wy), jnp.asarray(Xt), jnp.asarray(Yt)


def _run(pool, attack, agg="rfa", rounds=120):
    wx, wy, Xt, Yt = pool
    kwargs = (("n", 10), ("f", 2)) if attack == "alie" else ()
    byz = ByzConfig(aggregator=agg, mixing="bucketing", s=2,
                    attack=attack, attack_kwargs=kwargs, n_byzantine=0)
    sim = CrossDeviceSim(loss_fn=nll_loss, byz=byz, n_clients=50,
                         byz_frac=0.1, clients_per_round=10, lr=1.0,
                         batch_size=16, server_momentum=0.9)
    params = init_mlp(jax.random.PRNGKey(1))
    _, hist = sim.run(params, wx, wy, rounds, jax.random.PRNGKey(2),
                      eval_fn=lambda p: accuracy(p, Xt, Yt),
                      eval_every=rounds)
    return hist["eval"][-1]


def test_cross_device_learns_without_attack(pool):
    assert _run(pool, "none") > 0.75


def test_cross_device_defends_bitflip(pool):
    assert _run(pool, "bitflip") > 0.7


def test_cross_device_defends_ipm_with_acclip(pool):
    """Remark 7 with the beyond-paper agnostic clipper: no momentum state on
    clients, no tau tuning on the server."""
    assert _run(pool, "ipm", agg="acclip") > 0.7


def test_attack_key_independent_of_aggregator_key(pool):
    """Regression: ``step`` used to pass the SAME split (k_agg) to both the
    attack and the aggregation — a correlated attacker that effectively
    observes the defense's resampling permutation. The attack must get its
    own dedicated split."""
    from repro.training import cross_device as cd

    wx, wy, *_ = pool
    byz = ByzConfig(aggregator="rfa", mixing="resampling", s=2, attack="alie",
                    attack_kwargs=(("n", 10), ("f", 2)), n_byzantine=0)
    sim = CrossDeviceSim(loss_fn=nll_loss, byz=byz, n_clients=50,
                         byz_frac=0.1, clients_per_round=10, lr=0.1)
    state = sim.init_state(init_mlp(jax.random.PRNGKey(1)))

    seen = {}
    real_attack = sim.attack
    real_agg = cd.packed_aggregate

    def spy_attack(xs, byz_mask, st=None, key=None):
        seen["attack"] = key
        return real_attack(xs, byz_mask, st, key=key)

    def spy_agg(xs, aggregator, key=None, **kw):
        seen["agg"] = key
        return real_agg(xs, aggregator, key=key, **kw)

    sim.attack = spy_attack
    cd.packed_aggregate, orig = spy_agg, cd.packed_aggregate
    try:
        # run the undecorated step (eager) so the spies see concrete keys
        sim.step.__wrapped__(sim, state, wx, wy, jax.random.PRNGKey(3))
    finally:
        cd.packed_aggregate = orig
        sim.attack = real_attack

    assert seen["attack"] is not None and seen["agg"] is not None
    assert not np.array_equal(np.asarray(seen["attack"]),
                              np.asarray(seen["agg"]))


def test_cohort_byzantine_count_matches_pool_fraction(pool):
    wx, wy, *_ = pool
    byz = ByzConfig(aggregator="mean", attack="none")
    sim = CrossDeviceSim(loss_fn=nll_loss, byz=byz, n_clients=50,
                         byz_frac=0.1, clients_per_round=20, lr=0.1)
    state = sim.init_state(init_mlp(jax.random.PRNGKey(1)))
    counts = []
    for t in range(20):
        state, m = sim.step(state, wx, wy, jax.random.PRNGKey(t))
        counts.append(int(m["byz_in_cohort"]))
    # E[byz per cohort] = 20 * 0.1 = 2
    assert 0.5 < np.mean(counts) < 5.0
