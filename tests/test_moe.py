"""MoE layer: routing correctness, capacity drops, aux losses, oracle check."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import moe


def _cfg(**kw):
    cfg = smoke_config("olmoe-1b-7b")
    return dataclasses.replace(cfg, dtype="float32", **kw)


def naive_moe(p, x, cfg):
    """Dense oracle: every token through every expert, gated by renormalized
    top-k softmax (no capacity limit)."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    logits = xt @ p["router"]
    gates = jax.nn.softmax(logits, axis=-1)
    topg, topi = jax.lax.top_k(gates, cfg.experts_per_token)
    topg = topg / topg.sum(-1, keepdims=True)
    E = cfg.n_experts
    outs = []
    for e in range(E):
        if "w_gate" in p:
            act = jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_up"][e])
        else:
            act = jax.nn.gelu(xt @ p["w_up"][e], approximate=True)
        outs.append(act @ p["w_down"][e])
    outs = jnp.stack(outs, axis=1)  # [T, E, D]
    mask = jnp.zeros((xt.shape[0], E)).at[
        jnp.arange(xt.shape[0])[:, None], topi].set(topg)
    out = jnp.einsum("te,ted->td", mask, outs)
    for i in range(cfg.n_shared_experts):
        from repro.models.layers import mlp_block
        out = out + mlp_block(p[f"shared_{i}"], xt, cfg.mlp_kind)
    return out.reshape(B, S, D)


def test_moe_matches_dense_oracle_no_drops(key):
    """With capacity_factor large enough that nothing drops, the sort-based
    dispatch equals the dense oracle exactly."""
    cfg = _cfg(capacity_factor=8.0)
    p = moe.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model)) * 0.5
    out, aux = moe.moe_layer(p, x, cfg)
    assert float(aux["moe_drop_frac"]) == 0.0
    expect = naive_moe(p, x, cfg)
    np.testing.assert_allclose(out, expect, rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_tokens(key):
    """A tiny capacity factor forces drops; outputs stay finite and the drop
    fraction is reported."""
    cfg = _cfg(capacity_factor=0.1)
    p = moe.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, cfg.d_model))
    out, aux = moe.moe_layer(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert 0.0 < float(aux["moe_drop_frac"]) < 1.0


def test_load_balance_loss_bounds(key):
    """Switch LB loss: >= 1 always (Cauchy-Schwarz), == E for a collapsed
    router, ~1 for a uniform router."""
    cfg = _cfg()
    p = moe.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, cfg.d_model))
    _, aux = moe.moe_layer(p, x, cfg)
    assert float(aux["moe_lb_loss"]) >= 1.0 - 1e-3

    # collapsed router: all tokens to expert 0
    p2 = dict(p)
    p2["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
    _, aux2 = moe.moe_layer(p2, x, cfg)
    assert float(aux2["moe_lb_loss"]) > float(aux["moe_lb_loss"])


def test_expert_capacity_formula():
    cfg = _cfg(capacity_factor=1.25)
    C = moe.expert_capacity(1024, cfg)
    expect = int(np.ceil(cfg.experts_per_token * 1024 / cfg.n_experts * 1.25))
    assert C == max(8, expect)


def test_moe_grads_flow_to_all_used_experts(key):
    cfg = _cfg(capacity_factor=8.0)
    p = moe.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, cfg.d_model))

    def loss(p):
        out, _ = moe.moe_layer(p, x, cfg)
        return jnp.sum(out ** 2)

    g = jax.grad(loss)(p)
    # with 64 tokens over 4 experts, every expert receives tokens whp
    gn = jnp.linalg.norm(g["w_up"].reshape(cfg.n_experts, -1), axis=1)
    assert bool(jnp.all(gn > 0))
