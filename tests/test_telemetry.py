"""In-graph telemetry engine (repro/telemetry): registry + catalogue, the
in-flight accumulator's zero-overhead-off contract, probe math, JSONL event
schema, ring-buffered timing, telemetry through the packed engine and both
simulators (ALIE must be VISIBLE in the traces), jit-cache stability, and
the serving engine's structured events."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ByzConfig
from repro.core.aragg import RobustAggregator
from repro.distributed.packing import packed_aggregate
from repro.telemetry import (EventLog, InflightMetrics, MetricSpec, RingTimer,
                             catalogue, get_metric, phase, register,
                             validate_event, validate_jsonl)
from repro.telemetry import probes


# ============================================================== registry
class TestRegistry:
    def test_catalogue_sorted_and_specs_valid(self):
        cat = catalogue()
        assert len(cat) >= 25
        names = [s.name for s in cat]
        assert names == sorted(names)
        for s in cat:
            assert isinstance(s, MetricSpec) and s.doc

    def test_unregistered_metric_raises(self):
        with pytest.raises(KeyError, match="unregistered"):
            get_metric("no_such_metric")

    def test_reregistration_same_spec_ok_conflict_raises(self):
        spec = get_metric("agg_norm")
        assert register("agg_norm", spec.phase, spec.kind, spec.doc) == spec
        with pytest.raises(ValueError, match="already registered"):
            register("agg_norm", spec.phase, spec.kind, "different doc")

    def test_invalid_phase_or_kind_rejected(self):
        with pytest.raises(ValueError):
            MetricSpec("x", "nonsense", "scalar", "d")
        with pytest.raises(ValueError):
            MetricSpec("x", "sim", "nonsense", "d")


# =============================================================== inflight
class TestInflightMetrics:
    def test_disabled_never_evaluates_lazy_value(self):
        tm = InflightMetrics(False)
        assert not tm

        def bomb():
            raise AssertionError("lazy probe evaluated with telemetry off")

        tm.put("agg_norm", bomb)
        tm.update({"loss": bomb})
        assert tm.tree() == {}

    def test_enabled_records_and_invokes_lazy(self):
        tm = InflightMetrics(True)
        tm.put("agg_norm", lambda: jnp.float32(3.0))
        tm.put("loss", jnp.float32(1.5))
        tree = tm.tree()
        assert set(tree) == {"agg_norm", "loss"}
        assert float(tree["agg_norm"]) == 3.0

    def test_enabled_refuses_unregistered_names(self):
        tm = InflightMetrics(True)
        with pytest.raises(KeyError, match="unregistered"):
            tm.put("not_in_catalogue", 1.0)


# ================================================================= probes
def test_bucket_dispersion_from_gram_matches_direct(key):
    y = jax.random.normal(key, (6, 40), jnp.float32)
    direct = probes.bucket_dispersion(y)
    from_gram = probes.bucket_dispersion_from_gram(y @ y.T)
    np.testing.assert_allclose(np.asarray(from_gram), np.asarray(direct),
                               rtol=1e-5, atol=1e-4)


def test_phase_marker_is_computation_transparent(key):
    x = jax.random.normal(key, (8,), jnp.float32)

    @jax.jit
    def with_marker(v):
        with phase("unit_test"):
            return jnp.sum(v * v)

    np.testing.assert_array_equal(np.asarray(with_marker(x)),
                                  np.asarray(jax.jit(lambda v: jnp.sum(v * v))(x)))
    # named_scope lands in the compiled program's op_name METADATA only —
    # this is what lets coll_probe attribute collective bytes to phases
    # without the markers ever changing the collective budget
    assert "telemetry/unit_test" in with_marker.lower(x).compile().as_text()


# ================================================================= events
class TestEventLog:
    def test_memory_log_and_all_kinds(self):
        with EventLog(run_id="t") as log:
            log.run_meta(script="unit")
            log.round(0, {"agg_norm": 1.0, "byz_mask": [True, False]})
            log.bench_row("bench", {"cell": "a"}, {"mean_us": 2.0})
            log.probe("p", {"x": 1})
            log.serve({"serve_queue_depth": 0})
        kinds = [e["kind"] for e in log.events]
        assert kinds == ["run_meta", "round", "bench_row", "probe", "serve"]
        for e in log.events:
            validate_event(e)  # already validated on emit; idempotent

    def test_round_event_rejects_unregistered_metric(self):
        log = EventLog()
        with pytest.raises(ValueError, match="catalogue"):
            log.round(0, {"made_up_metric": 1.0})

    def test_numpy_values_coerced_to_json(self, tmp_path):
        path = tmp_path / "ev.jsonl"
        with EventLog(path, run_id="t") as log:
            log.round(3, {"agg_norm": np.float32(2.5),
                          "worker_weights": jnp.ones((4,), jnp.float32)})
        events = validate_jsonl(path)
        assert events[0]["round"] == 3
        assert events[0]["metrics"]["worker_weights"] == [1.0] * 4
        # every line is plain JSON (no numpy reprs survived)
        for line in path.read_text().splitlines():
            json.loads(line)

    def test_validate_jsonl_names_offending_line(self, tmp_path):
        path = tmp_path / "bad.jsonl"
        good = {"kind": "probe", "t": 1.0, "name": "p", "data": {}}
        path.write_text(json.dumps(good) + "\n" + "{not json}\n")
        with pytest.raises(ValueError, match="bad.jsonl:2"):
            validate_jsonl(path)
        path.write_text(json.dumps({"kind": "nope", "t": 1.0}) + "\n")
        with pytest.raises(ValueError, match="unknown event kind"):
            validate_jsonl(path)


class TestRingTimer:
    def test_window_summary(self):
        rt = RingTimer(capacity=4)
        for s in (1.0, 2.0, 3.0, 4.0, 5.0):   # 1.0 falls out of the ring
            rt.record(s)
        s = rt.summary()
        assert s["count"] == 4 and s["total"] == 5
        assert s["mean_s"] == pytest.approx(3.5)
        assert s["max_s"] == 5.0
        assert len(rt) == 4

    def test_context_manager_and_misuse(self):
        rt = RingTimer()
        with rt:
            pass
        assert len(rt) == 1 and rt.summary()["mean_s"] >= 0.0
        with pytest.raises(RuntimeError):
            rt.stop()
        with pytest.raises(ValueError):
            RingTimer(0)


# ================================================== packed engine metrics
EXPECTED_KEYS = {
    "rfa": {"rfa_residual", "rfa_resid_norms", "rfa_iters"},
    "cm": {"cm_worker_dev"},
    "tm": {"tm_trim_frac"},
    "cclip": {"cclip_lam", "cclip_clip_frac", "cclip_tau"},
    "krum": {"krum_scores", "krum_selected"},
}


@pytest.mark.parametrize("agg", sorted(EXPECTED_KEYS))
def test_packed_aggregate_stats_on_vs_off(key, agg):
    """Telemetry-on output stays within fusion-level tolerance of off, the
    rule-specific metrics + layout counters ride out, and the off-path info
    carries no telemetry tree at all."""
    xs = jax.random.normal(key, (12, 600), jnp.float32)
    kwargs = {"krum": {"n_byzantine": 2}, "cclip": {"tau": 3.0},
              "tm": {"n_trim": 2}}.get(agg, {})
    ra = RobustAggregator.from_spec(agg, mixing="bucketing", s=2, **kwargs)
    k = jax.random.PRNGKey(9)
    out_off, info_off = packed_aggregate(xs, ra, key=k, block_d=256,
                                         with_info=True)
    assert "telemetry" not in info_off
    out_on, info_on = packed_aggregate(xs, ra, key=k, block_d=256,
                                       telemetry=True, with_info=True)
    np.testing.assert_allclose(np.asarray(out_on), np.asarray(out_off),
                               rtol=2e-6, atol=2e-6)
    tele = info_on["telemetry"]
    missing = EXPECTED_KEYS[agg] - set(tele)
    assert not missing, f"{agg} telemetry missing {missing}: {sorted(tele)}"
    assert "bucket_dispersion" in tele
    for counter in ("sync_n_workers", "sync_n_params", "sync_n_pad",
                    "sync_ingress_bytes", "sync_egress_bytes"):
        assert counter in tele
    assert int(tele["sync_n_workers"]) == 12
    assert int(tele["sync_n_params"]) == 600
    assert int(tele["sync_ingress_bytes"]) == 12 * int(tele["sync_n_pad"]) * 4
    for v in tele.values():
        assert np.all(np.isfinite(np.asarray(v, np.float32)))


# ===================================================== attack visibility
@pytest.fixture(scope="module")
def alie_pool():
    from repro.data.partition import worker_datasets
    from repro.data.synthetic import make_train_test

    X, Y, _, _ = make_train_test(jax.random.PRNGKey(0), n_train=2500,
                                 n_test=100)
    wx, wy = worker_datasets(X, Y, n_good=20, n_byz=5, noniid=True)
    return jnp.asarray(wx), jnp.asarray(wy)


def _alie_sim(agg, telemetry=True, **agg_kwargs):
    from repro.models.mlp import nll_loss
    from repro.training.byzantine import ByzantineSim

    n, f = 25, 5
    byz = ByzConfig(aggregator=agg, mixing="none", attack="alie",
                    attack_kwargs=(("n", n), ("f", f)), n_byzantine=f,
                    worker_momentum=0.9, delta=f / n, **agg_kwargs)
    return ByzantineSim(loss_fn=nll_loss, byz=byz, n_workers=n,
                        n_byzantine=f, lr=0.1, batch_size=32,
                        telemetry=telemetry)


def test_alie_visible_in_telemetry(alie_pool):
    """The PR's headline demo: ALIE is designed to evade norm-based checks,
    but the per-worker traces still separate Byzantine from honest — ALIE
    rows hug the coordinatewise median abnormally tightly (low
    cm_worker_dev) and collect abnormally LOW Krum scores."""
    wx, wy = alie_pool
    f = 5

    from repro.models.mlp import init_mlp

    sim = _alie_sim("cm")
    _, hist = sim.run(init_mlp(jax.random.PRNGKey(1)), wx, wy, 15,
                      jax.random.PRNGKey(2))
    dev = hist["telemetry"]["cm_worker_dev"]       # [steps, 25]
    assert dev.shape == (15, 25)
    byz_mask = hist["telemetry"]["byz_mask"][0]
    assert byz_mask[:f].all() and not byz_mask[f:].any()
    late = dev[5:]
    assert late[:, :f].mean() < 0.6 * late[:, f:].mean(), (
        "ALIE workers should sit suspiciously CLOSE to the median")

    sim_k = _alie_sim("krum")
    _, hist_k = sim_k.run(init_mlp(jax.random.PRNGKey(1)), wx, wy, 15,
                          jax.random.PRNGKey(2))
    scores = hist_k["telemetry"]["krum_scores"]    # [steps, 25]
    assert scores.shape == (15, 25)
    late_s = scores[5:]
    assert late_s[:, :f].mean() < late_s[:, f:].mean(), (
        "ALIE workers should collect low (central) Krum scores")


def test_telemetry_off_history_is_seed_shape(alie_pool):
    """telemetry=False must leave the run history exactly as the seed had
    it — no 'telemetry' key, no metric accumulation."""
    wx, wy = alie_pool
    from repro.models.mlp import init_mlp

    sim = _alie_sim("cm", telemetry=False)
    _, hist = sim.run(init_mlp(jax.random.PRNGKey(1)), wx, wy, 3,
                      jax.random.PRNGKey(2))
    assert "telemetry" not in hist
    assert sorted(hist) == ["eval", "step", "zeta_sq"]


# ============================================== cross-device + jit cache
def test_cross_device_telemetry_no_retrace(alie_pool):
    """The telemetry flag lives on static ``self``: a telemetry-on sim must
    compile its step ONCE and reuse it every round (no per-round retrace,
    no signature change from threading the metrics pytree out)."""
    from repro.models.mlp import init_mlp, nll_loss
    from repro.training.cross_device import CrossDeviceSim

    wx, wy = alie_pool
    byz = ByzConfig(aggregator="rfa", mixing="bucketing", s=2, attack="alie",
                    attack_kwargs=(("n", 10), ("f", 2)), n_byzantine=0)
    sim = CrossDeviceSim(loss_fn=nll_loss, byz=byz, n_clients=25,
                         byz_frac=0.2, clients_per_round=10, lr=0.1,
                         batch_size=16, telemetry=True)
    before = CrossDeviceSim.step._cache_size()
    _, hist = sim.run(init_mlp(jax.random.PRNGKey(1)), wx, wy, 4,
                      jax.random.PRNGKey(2))
    assert CrossDeviceSim.step._cache_size() == before + 1
    tele = hist["telemetry"]
    assert tele["byz_mask"].shape == (4, 10)
    assert tele["rfa_residual"].ndim == 2 and tele["rfa_residual"].shape[0] == 4
    for name in tele:
        get_metric(name)  # everything in the history is catalogued
    # rounds -> JSONL -> validator: the loop the CI smoke job exercises
    with EventLog(run_id="unit") as log:
        for t in range(4):
            log.round(t, {k: v[t] for k, v in tele.items()})
    assert len(log.events) == 4


# ================================================================ serving
def test_serve_engine_emits_validated_events():
    from repro.configs import smoke_config
    from repro.models import transformer as tfm
    from repro.serving import Request, ServeEngine

    cfg = smoke_config("tinyllama-1.1b")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    log = EventLog(run_id="serve_test")
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64, event_log=log)
    eng.submit(Request(uid=1, prompt=[5, 17, 99], max_new_tokens=4))
    eng.submit(Request(uid=2, prompt=[42], max_new_tokens=3))
    done = eng.run_until_drained()
    assert set(done) == {1, 2}

    serve_events = [e for e in log.events if e["kind"] == "serve"]
    assert len(serve_events) == eng.steps_total > 0
    final = eng.stats()
    assert final["serve_tokens_total"] == 4 + 3 == eng.tokens_total
    assert final["serve_queue_depth"] == 0 and final["serve_active_slots"] == 0
    assert final["serve_decode_step_s"] > 0.0
    assert final["serve_admit_latency_s"] >= 0.0
    for name in final:
        get_metric(name)
    # request-level latency stamps are ordered
    for req in done.values():
        assert req.t_submit is not None and req.t_admit >= req.t_submit
