"""Attention impls: blockwise (flash-style) == xla reference; ring-buffer
decode cache; sliding windows; GQA head expansion."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import attention as attn
import dataclasses


def _cfg(**kw):
    return dataclasses.replace(smoke_config("tinyllama-1.1b"), **kw)


def _qkv(key, cfg, B=2, S=64):
    p = attn.init_attention(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.d_model),
                          jnp.float32)
    return p, x


@pytest.mark.parametrize("window", [0, 32])
@pytest.mark.parametrize("S", [64, 96])
def test_blockwise_matches_xla(key, window, S):
    cfg = _cfg(sliding_window=window, attn_block_q=32, attn_block_kv=32)
    p, x = _qkv(key, cfg, S=S)
    positions = jnp.arange(S)[None, :]
    out_xla = attn.attention(p, x, cfg, positions, impl="xla")
    out_blk = attn.attention(p, x, cfg, positions, impl="blockwise")
    np.testing.assert_allclose(out_xla, out_blk, rtol=2e-4, atol=2e-4)


def test_gqa_expand_kv():
    k = jnp.arange(2 * 3 * 2 * 4).reshape(2, 3, 2, 4).astype(jnp.float32)
    out = attn._expand_kv(k, 6)  # 2 kv heads -> 6 heads, rep 3
    assert out.shape == (2, 3, 6, 4)
    np.testing.assert_array_equal(out[:, :, 0], k[:, :, 0])
    np.testing.assert_array_equal(out[:, :, 2], k[:, :, 0])
    np.testing.assert_array_equal(out[:, :, 3], k[:, :, 1])


def test_decode_ring_buffer_matches_full(key):
    """Decoding with a FULL-length cache matches forward attention exactly."""
    cfg = _cfg()
    S = 12
    p, x = _qkv(key, cfg, B=1, S=S)
    positions = jnp.arange(S)[None, :]
    full = attn.attention(p, x, cfg, positions, impl="xla")

    cache = attn.init_kv_cache(1, S, cfg, jnp.float32)
    for t in range(S):
        out, cache = attn.decode_attention(p, x[:, t:t + 1], cache, cfg,
                                           jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(out[:, 0], full[:, t], rtol=2e-4, atol=2e-4)


def test_decode_windowed_ring_buffer(key):
    """A ring cache of capacity = window reproduces sliding-window attention."""
    W = 8
    cfg = _cfg(sliding_window=W)
    S = 20
    p, x = _qkv(key, cfg, B=1, S=S)
    positions = jnp.arange(S)[None, :]
    full = attn.attention(p, x, cfg, positions, impl="xla")

    cache = attn.init_kv_cache(1, W, cfg, jnp.float32)
    for t in range(S):
        out, cache = attn.decode_attention(p, x[:, t:t + 1], cache, cfg,
                                           jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(out[:, 0], full[:, t], rtol=5e-4, atol=5e-4)


def test_causality(key):
    """Changing future tokens never changes past outputs."""
    cfg = _cfg()
    S = 16
    p, x = _qkv(key, cfg, B=1, S=S)
    positions = jnp.arange(S)[None, :]
    out1 = attn.attention(p, x, cfg, positions, impl="xla")
    x2 = x.at[:, S // 2:].set(jax.random.normal(jax.random.fold_in(key, 7),
                                                x[:, S // 2:].shape))
    out2 = attn.attention(p, x2, cfg, positions, impl="xla")
    np.testing.assert_allclose(out1[:, : S // 2], out2[:, : S // 2],
                               rtol=1e-5, atol=1e-5)


def test_divisor_block_handles_prefix_lengths():
    """Prefix-extended sequence lengths (4096+256 etc.) get a dividing
    block; powers of two keep the requested block."""
    assert attn._divisor_block(4096, 512) == 512
    assert 4352 % attn._divisor_block(4352, 512) == 0
    assert attn._divisor_block(4352, 512) == 272  # 4352 = 2^8 * 17
    assert 33024 % attn._divisor_block(33024, 1024) == 0
    assert attn._divisor_block(7, 512) == 7


def test_blockwise_ragged_seq_matches_xla(key):
    """Non-power-of-two S (prefix-extended) must still be exact."""
    cfg = _cfg(attn_block_q=32, attn_block_kv=32)
    S = 72  # 72 % 32 != 0 -> divisor fallback (24)
    p, x = _qkv(key, cfg, S=S)
    positions = jnp.arange(S)[None, :]
    out_xla = attn.attention(p, x, cfg, positions, impl="xla")
    out_blk = attn.attention(p, x, cfg, positions, impl="blockwise")
    np.testing.assert_allclose(out_xla, out_blk, rtol=2e-4, atol=2e-4)
