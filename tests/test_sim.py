"""Integration: the ByzantineSim harness reproduces the paper's directional
claims at a reduced scale (full-scale reproduction lives in benchmarks/)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ByzConfig
from repro.data.partition import worker_datasets
from repro.data.synthetic import make_train_test
from repro.models.mlp import accuracy, init_mlp, nll_loss
from repro.training.byzantine import ByzantineSim, label_flip_targets


@pytest.fixture(scope="module")
def task():
    key = jax.random.PRNGKey(0)
    X, Y, Xt, Yt = make_train_test(key, n_train=3000, n_test=600)
    return X, Y, Xt, Yt


def _run(task, byz: ByzConfig, n=10, f=2, steps=120, noniid=True, lr=0.1, seed=0):
    X, Y, Xt, Yt = task
    wx, wy = worker_datasets(X, Y, n_good=n - f, n_byz=f, noniid=noniid, seed=seed)
    sim = ByzantineSim(loss_fn=nll_loss, byz=byz, n_workers=n, n_byzantine=f,
                       lr=lr, batch_size=32)
    params = init_mlp(jax.random.PRNGKey(1 + seed))
    state, hist = sim.run(params, jnp.asarray(wx), jnp.asarray(wy), steps,
                          jax.random.PRNGKey(2 + seed),
                          eval_fn=lambda p: accuracy(p, Xt, Yt),
                          eval_every=steps)
    return hist["eval"][-1]


def test_mean_learns_noniid_no_attack(task):
    acc = _run(task, ByzConfig(aggregator="mean", attack="none"), f=0)
    assert acc > 0.75, acc


def test_krum_fails_noniid_bucketing_fixes(task):
    """Paper §3.1 / Tables 1 vs 3: vanilla Krum underperforms on non-iid data
    even with NO Byzantine workers; bucketing closes most of the gap."""
    vanilla = _run(task, ByzConfig(aggregator="krum", mixing="none",
                                   attack="none", n_byzantine=0), f=0)
    mixed = _run(task, ByzConfig(aggregator="krum", mixing="bucketing", s=2,
                                 attack="none", n_byzantine=0), f=0)
    assert mixed > vanilla + 0.05, (vanilla, mixed)


def test_mimic_hurts_cm_bucketing_helps(task):
    """Paper Tables 2 vs 4 (CM row): mimic on non-iid data cripples CM;
    bucketing recovers most accuracy."""
    plain = _run(task, ByzConfig(aggregator="cm", mixing="none", attack="mimic",
                                 n_byzantine=2))
    mixed = _run(task, ByzConfig(aggregator="cm", mixing="bucketing", s=2,
                                 attack="mimic", n_byzantine=2))
    # at this reduced scale (n=10, f=2, easy task) mimic only dents CM; the
    # full-strength effect (paper Tables 2/4, n=25) is reproduced by
    # benchmarks/table2.py + table3_4.py. Here we assert bucketing stays in
    # the same accuracy band and the model trains under attack either way.
    assert mixed > plain - 0.07, (plain, mixed)
    assert mixed > 0.5, mixed


def test_cclip_robust_to_ipm(task):
    """Fig 2/3: CCLIP + momentum + bucketing withstands IPM."""
    byz = ByzConfig(aggregator="cclip", mixing="bucketing", s=2,
                    worker_momentum=0.9, attack="ipm",
                    attack_kwargs=(("eps", 0.1),), n_byzantine=2)
    acc = _run(task, byz, lr=0.5)  # EMA momentum scales updates by (1-beta)
    assert acc > 0.6, acc


def test_bitflip_defended_by_rfa(task):
    byz = ByzConfig(aggregator="rfa", mixing="bucketing", s=2,
                    attack="bitflip", n_byzantine=2)
    acc = _run(task, byz)
    assert acc > 0.6, acc


def test_label_flip_transform():
    y = jnp.asarray([0, 4, 9])
    assert (label_flip_targets(y) == jnp.asarray([9, 5, 0])).all()


def test_sim_metrics_finite(task):
    X, Y, Xt, Yt = task
    byz = ByzConfig(aggregator="rfa", mixing="bucketing", s=2, attack="alie",
                    attack_kwargs=(("n", 10), ("f", 2)), n_byzantine=2)
    wx, wy = worker_datasets(X, Y, n_good=8, n_byz=2, noniid=True)
    sim = ByzantineSim(loss_fn=nll_loss, byz=byz, n_workers=10, n_byzantine=2,
                       lr=0.05, batch_size=16)
    state = sim.init_state(init_mlp(jax.random.PRNGKey(3)))
    state, metrics = sim.step(state, jnp.asarray(wx), jnp.asarray(wy),
                              jax.random.PRNGKey(4))
    for v in metrics.values():
        assert bool(jnp.isfinite(v))
