"""shard_map-partitioned kernel engine (distributed/shard_kernels.py) and the
param-sharded egress (packing.unpack_to_shardings) on a FORCED multi-device
host platform.

jax locks the device count at first init, and conftest deliberately does NOT
force it (every other test file sees the real single device). So this module
runs its real assertions only when >= 8 devices exist, and otherwise a single
launcher test re-invokes pytest on this file in a subprocess with
``--xla_force_host_platform_device_count=8`` — the pattern the quick CI job
uses directly.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

MULTI = jax.device_count() >= 8

pytestmark = pytest.mark.skipif(
    not MULTI and os.environ.get("_SHARD_ENGINE_CHILD") == "1",
    reason="child process failed to force 8 host devices",
)


def test_relaunch_on_forced_8_device_host():
    """Single-device launcher: run this file's real tests on 8 forced CPU
    devices in a child process."""
    if MULTI:
        pytest.skip("already multi-device; real tests run directly")
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    env["_SHARD_ENGINE_CHILD"] = "1"
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", __file__,
         "--deselect", f"{__file__}::test_relaunch_on_forced_8_device_host"],
        env=env, capture_output=True, text=True, timeout=1800,
    )
    assert proc.returncode == 0, (
        f"forced-8-device run failed:\n{proc.stdout[-4000:]}\n"
        f"{proc.stderr[-2000:]}")


if MULTI:
    from repro.core.aragg import RobustAggregator
    from repro.distributed import packing, shard_kernels
    from repro.distributed.robust_sync import robust_gradient_sync
    from repro.distributed.sharding import param_shardings
    from repro.kernels import ops
    from repro.launch.hlo_analysis import collective_bytes
    from repro.launch.mesh import make_host_mesh

    BLOCK_D = 256
    W = 8

    def _mesh():
        return make_host_mesh(data=4, model=2)

    def _tree(key, W=W):
        ks = jax.random.split(key, 3)
        return {
            "w": jax.random.normal(ks[0], (W, 16, 48), jnp.float32),
            "b": jax.random.normal(ks[1], (W, 33), jnp.float32),
            "v": jax.random.normal(ks[2], (W, 257), jnp.float32),
        }

    def _stack(key, d=1111):
        return jax.random.normal(key, (W, d), jnp.float32)

    # -------------------------------------------- sharded kernel primitives
    def test_sharded_gram_matches_single_device(key):
        xs = _stack(key)
        mesh = _mesh()
        got = jax.jit(lambda b: shard_kernels.gram(b, mesh, block_d=BLOCK_D))(xs)
        want = xs @ xs.T
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_sharded_mix_apply_matches_single_device(key):
        xs = _stack(key)
        m = jax.random.normal(jax.random.PRNGKey(1), (5, W), jnp.float32)
        mesh = _mesh()
        got = jax.jit(
            lambda mm, b: shard_kernels.mix_apply(mm, b, mesh, block_d=BLOCK_D)
        )(m, xs)
        np.testing.assert_allclose(got, m @ xs, rtol=1e-5, atol=1e-5)
        assert got.shape == xs.shape[:0] + (5, xs.shape[1])

    def test_sharded_cm_matches_single_device(key):
        xs = _stack(key)
        mesh = _mesh()
        got = jax.jit(lambda b: shard_kernels.cm_aggregate(b, mesh,
                                                           block_d=BLOCK_D))(xs)
        np.testing.assert_allclose(got, jnp.median(xs, axis=0),
                                   rtol=1e-6, atol=1e-6)

    def test_sharded_tm_matches_single_device(key):
        xs = _stack(key)
        mesh = _mesh()
        got = jax.jit(lambda b: shard_kernels.tm_aggregate(b, 2, mesh,
                                                           block_d=BLOCK_D))(xs)
        want = jnp.mean(jnp.sort(xs, axis=0)[2:-2], axis=0)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_sharded_residual_norms_both_forms(key):
        xs = _stack(key)
        mesh = _mesh()
        coeffs = jax.nn.softmax(jnp.arange(W, dtype=jnp.float32))
        center = coeffs @ xs
        want = jnp.sum((xs - center[None, :]) ** 2, axis=1)
        got_c = jax.jit(lambda b, c: shard_kernels.residual_norms(
            b, c, mesh=mesh, block_d=BLOCK_D))(xs, coeffs)
        got_v = jax.jit(lambda b, v: shard_kernels.residual_norms(
            b, center=v, mesh=mesh, block_d=BLOCK_D))(xs, center)
        np.testing.assert_allclose(got_c, want, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(got_v, want, rtol=1e-4, atol=1e-4)

    def test_sharded_cclip_iter_matches_single_device(key):
        xs = _stack(key)
        mesh = _mesh()
        v = jnp.mean(xs, axis=0)
        lam = jnp.minimum(
            1.0, 3.0 / jnp.sqrt(jnp.sum((xs - v) ** 2, axis=1) + 1e-12))
        v_ref, r2_ref = ops.cclip_iter(xs, v, lam, block_d=BLOCK_D)
        v_got, r2_got = jax.jit(lambda b, vv, ll: shard_kernels.cclip_fused_iter(
            b, vv, ll, mesh, block_d=BLOCK_D))(xs, v, lam)
        np.testing.assert_allclose(v_got, v_ref, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(r2_got, r2_ref, rtol=1e-4, atol=1e-4)

    def test_sharded_compositions_match_single_device(key):
        xs = _stack(key)
        mesh = _mesh()
        np.testing.assert_allclose(
            jax.jit(lambda b: shard_kernels.rfa_aggregate(b, mesh,
                                                          block_d=BLOCK_D))(xs),
            ops.rfa_aggregate(xs, block_d=BLOCK_D), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(
            jax.jit(lambda b: shard_kernels.cclip_aggregate(
                b, 3.0, mesh, block_d=BLOCK_D))(xs),
            ops.cclip_aggregate(xs, 3.0, block_d=BLOCK_D),
            rtol=1e-4, atol=1e-4)

    # ------------------------------------------------- engine: kernels vs jnp
    RULES = [
        ("krum", {"n_byzantine": 2}),
        ("rfa", {}),
        ("cclip", {"tau": 3.0}),
        ("cm", {}),
        ("tm", {"n_trim": 2}),
        ("mean", {}),
    ]
    MIXINGS = ["none", "bucketing", "resampling"]

    @pytest.mark.parametrize("agg,kwargs", RULES, ids=[r[0] for r in RULES])
    @pytest.mark.parametrize("mixing", MIXINGS)
    def test_kernel_path_matches_gspmd_jnp_path(key, agg, kwargs, mixing):
        """On a real multi-device mesh the shard_map kernel route must agree
        with the GSPMD-partitioned jnp route to fp32 tolerance (per-device
        block order differs, so not bit-for-bit)."""
        tree = _tree(key)
        mesh = _mesh()
        ra = RobustAggregator.from_spec(agg, mixing=mixing, s=2, **kwargs)
        agg_key = jax.random.PRNGKey(11)
        with mesh:
            out_k, _ = jax.jit(lambda t, k: robust_gradient_sync(
                t, ra, key=k, mesh=mesh, engine="packed", block_d=BLOCK_D,
                use_kernels=True))(tree, agg_key)
            out_j, _ = jax.jit(lambda t, k: robust_gradient_sync(
                t, ra, key=k, mesh=mesh, engine="packed", block_d=BLOCK_D,
                use_kernels=False))(tree, agg_key)
        for lk, lj in zip(jax.tree_util.tree_leaves(out_k),
                          jax.tree_util.tree_leaves(out_j)):
            np.testing.assert_allclose(np.asarray(lk), np.asarray(lj),
                                       rtol=5e-4, atol=5e-4)

    def test_no_silent_jnp_fallback_on_multi_device_mesh(key, monkeypatch):
        """use_kernels=True on a non-trivial mesh must route through the
        shard_map wrappers — RFA/CCLIP through the FUSED sharded
        compositions (no [W, W] Gram detour), CM/TM through the sharded
        selection kernels; the Gram route remains only for the rules that
        genuinely need the Gram matrix (krum, acclip)."""
        tree = _tree(key)
        mesh = _mesh()
        hits = {}
        for name in ("gram", "mix_apply", "cm_aggregate", "tm_aggregate",
                     "rfa_aggregate", "cclip_aggregate"):
            orig = getattr(shard_kernels, name)

            def wrapper(*a, _orig=orig, _n=name, **kw):
                hits[_n] = hits.get(_n, 0) + 1
                return _orig(*a, **kw)

            monkeypatch.setattr(packing.shard_kernels, name, wrapper)

        k = jax.random.PRNGKey(0)

        def run(spec, **kw):
            hits.clear()
            ra = RobustAggregator.from_spec(spec, mixing="bucketing", s=2, **kw)
            robust_gradient_sync(tree, ra, key=k, mesh=mesh, engine="packed",
                                 block_d=BLOCK_D, use_kernels=True)
            return dict(hits)

        h = run("rfa")
        assert h.get("rfa_aggregate") == 1 and "gram" not in h, h
        h = run("cclip", tau=3.0)
        assert h.get("cclip_aggregate") == 1 and "gram" not in h, h
        h = run("cm")
        assert h.get("cm_aggregate") == 1 and h.get("mix_apply") == 1, h
        h = run("tm", n_trim=2)
        assert h.get("tm_aggregate") == 1 and h.get("mix_apply") == 1, h
        h = run("krum", n_byzantine=2)
        assert h.get("gram") == 1 and h.get("mix_apply") == 1, h

    @pytest.mark.parametrize("agg,kwargs", [("cm", {}), ("tm", {"n_trim": 2})],
                             ids=["cm", "tm"])
    def test_sharded_cm_tm_bit_match_per_leaf_oracle(key, agg, kwargs):
        """The coordinatewise kernels are column-local (every output
        coordinate depends only on its own column, through the same static
        selection program), so the packed multi-device route must BIT-match
        the single-device per-leaf kernel oracle."""
        tree = _tree(key)
        mesh = _mesh()
        ra = RobustAggregator.from_spec(agg, mixing="bucketing", s=2, **kwargs)
        k = jax.random.PRNGKey(3)
        with mesh:
            packed, _ = jax.jit(lambda t, kk: robust_gradient_sync(
                t, ra, key=kk, mesh=mesh, engine="packed", block_d=BLOCK_D,
                use_kernels=True))(tree, k)
        oracle, _ = robust_gradient_sync(tree, ra, key=k, mesh=None,
                                         engine="per_leaf", block_d=BLOCK_D,
                                         use_kernels=True)
        for a, b in zip(jax.tree_util.tree_leaves(packed),
                        jax.tree_util.tree_leaves(oracle)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # ------------------------------------------------- param-sharded egress
    def test_param_sharded_egress_skips_replicated_buffer(key):
        """With out_shardings, the compiled HLO must not materialize the
        fully-replicated [n_pad] row, and egress collective bytes shrink.

        Every leaf here is FSDP-shardable (divisible by both mesh axes) —
        the case the param-sharded egress exists for. A leaf whose sharding
        comes out replicated still needs a gather of its own slice, and XLA
        may widen that to the full row."""
        mesh = _mesh()
        ks = jax.random.split(key, 3)
        tree = {
            "w": jax.random.normal(ks[0], (W, 16, 48), jnp.float32),
            "b": jax.random.normal(ks[1], (W, 8, 64), jnp.float32),
            "v": jax.random.normal(ks[2], (W, 4, 256), jnp.float32),
        }
        ra = RobustAggregator.from_spec("rfa", mixing="bucketing", s=2)
        packer = packing.packer_for(tree, block_d=BLOCK_D)
        n_pad = packer.n_pad
        shapes = jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), tree)
        out_sh = param_shardings(shapes, mesh, fsdp=True)

        def sync(t, k, out_shardings=None):
            out, _ = robust_gradient_sync(
                t, ra, key=k, mesh=mesh, engine="packed", block_d=BLOCK_D,
                use_kernels=False, out_shardings=out_shardings)
            return out

        k = jax.random.PRNGKey(5)
        with mesh:
            rep = jax.jit(sync).lower(tree, k).compile()
            par = jax.jit(
                lambda t, kk: sync(t, kk, out_shardings=out_sh)
            ).lower(tree, k).compile()
        rep_hlo, par_hlo = rep.as_text(), par.as_text()
        assert f"f32[{n_pad}]" in rep_hlo          # replicated egress row
        assert f"f32[{n_pad}]" not in par_hlo      # never materialized
        rep_bytes = sum(collective_bytes(rep_hlo).values())
        par_bytes = sum(collective_bytes(par_hlo).values())
        assert par_bytes < rep_bytes
        # and the values agree
        with mesh:
            o_rep = jax.jit(sync)(tree, k)
            o_par = jax.jit(lambda t, kk: sync(t, kk, out_shardings=out_sh))(tree, k)
        for a, b in zip(jax.tree_util.tree_leaves(o_rep),
                        jax.tree_util.tree_leaves(o_par)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)
