"""Tests for the three-layer static-analysis gate (``repro.analysis``).

Golden-HLO fixtures live in ``tests/golden_hlo/``; they pin the HLO text
parsers (shape bytes, start/done collective pairing) and the HLO rule
engine against hand-computed expectations, so a parser regression cannot
silently loosen the CI gate.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.analysis.ast_lint import lint_paths, lint_source
from repro.analysis.findings import ERROR, WARNING, Finding, Report
from repro.analysis.hlo_lint import (HloCheckSpec, lint_hlo, make_budget,
                                     write_budget)
from repro.launch.hlo_analysis import (_parse_shape_bytes, collective_bytes,
                                       collective_counts, iter_collectives)

HERE = os.path.dirname(os.path.abspath(__file__))
GOLDEN = os.path.join(HERE, "golden_hlo")
REPO = os.path.dirname(HERE)


def _golden(name):
    with open(os.path.join(GOLDEN, name), "r", encoding="utf-8") as fh:
        return fh.read()


# ===================================================== HLO text parsers
class TestParseShapeBytes:
    def test_simple(self):
        assert _parse_shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
        assert _parse_shape_bytes("bf16[4,128]") == 4 * 128 * 2

    def test_scalar_and_empty_dims(self):
        assert _parse_shape_bytes("f32[]") == 4
        assert _parse_shape_bytes("pred[]") == 1

    def test_tuple_sums_elements(self):
        assert _parse_shape_bytes("(f32[4]{0}, u32[2]{0})") == 16 + 8

    def test_fp8_dtypes(self):
        assert _parse_shape_bytes("f8e4m3fn[1024]") == 1024
        assert _parse_shape_bytes("f8e5m2[2,2]") == 4

    def test_f64(self):
        assert _parse_shape_bytes("f64[2]") == 16


class TestStartDonePairing:
    """tests/golden_hlo/start_done_pair.hlo: one all-gather-start/-done
    pair (start carries the (operand, result) tuple ≈ 2x payload), one
    plain all-reduce, one collective-permute."""

    def test_pair_counted_once_at_done(self):
        hlo = _golden("start_done_pair.hlo")
        counts = collective_counts(hlo)
        assert counts == {"all-gather": 1, "all-reduce": 1,
                          "collective-permute": 1}

    def test_pair_bytes_use_done_output_shape(self):
        hlo = _golden("start_done_pair.hlo")
        nbytes = collective_bytes(hlo)
        # done output f32[16,128], NOT the start tuple (8+16)*128*4
        assert nbytes["all-gather"] == 16 * 128 * 4
        assert nbytes["all-reduce"] == 8 * 128 * 4
        assert nbytes["collective-permute"] == 8 * 128 * 4

    def test_unpaired_start_still_counted(self):
        hlo = ("ENTRY %m (p0: f32[8]) -> f32[8] {\n"
               "  %p0 = f32[8]{0} parameter(0)\n"
               "  %s = (f32[8]{0}, f32[16]{0}) all-gather-start(%p0)\n"
               "  ROOT %r = f32[8]{0} copy(%p0)\n"
               "}\n")
        counts = collective_counts(hlo)
        assert counts == {"all-gather": 1}
        # no done to pair with: the start's tuple shape is all we have
        assert collective_bytes(hlo)["all-gather"] == (8 + 16) * 4

    def test_iter_collectives_line_numbers(self):
        hlo = _golden("start_done_pair.hlo")
        kinds = sorted(kind for kind, _, _ in iter_collectives(hlo))
        assert kinds == ["all-gather", "all-reduce", "collective-permute"]
        for _, _, line_no in iter_collectives(hlo):
            assert line_no >= 1


# ========================================================== HLO rules
class TestHloRules:
    """tests/golden_hlo/lint_rules.hlo: one f64 convert, one host
    callback custom-call, one infeed, and f32[2304] buffers."""

    def _rules(self, findings):
        return sorted({f.rule for f in findings})

    def test_f64_host_transfer_replicated(self):
        hlo = _golden("lint_rules.hlo")
        spec = HloCheckSpec(name="golden", forbid_replicated=("f32[2304]",),
                            check_budget=False)
        findings = lint_hlo(hlo, spec, backend="cpu")
        assert self._rules(findings) == ["hlo-f64", "hlo-host-transfer",
                                         "hlo-replicated-egress"]
        # both the callback custom-call AND the infeed are host transfers
        assert sum(f.rule == "hlo-host-transfer" for f in findings) == 2
        assert all(f.severity == ERROR for f in findings)

    def test_clean_program_passes(self):
        hlo = _golden("start_done_pair.hlo")
        spec = HloCheckSpec(name="clean", check_budget=False)
        assert lint_hlo(hlo, spec, backend="cpu") == []

    def test_pallas_rule_gated_to_accelerator_backends(self):
        hlo = _golden("start_done_pair.hlo")  # no pallas custom-call
        spec = HloCheckSpec(name="k", expect_pallas_custom_call=True,
                            check_budget=False)
        # CPU interpret-mode Pallas lowers to plain HLO: rule must not fire
        assert lint_hlo(hlo, spec, backend="cpu") == []
        tpu = lint_hlo(hlo, spec, backend="tpu")
        assert self._rules(tpu) == ["hlo-pallas-missing"]
        with_kernel = hlo + ('  %k = f32[8]{0} custom-call(%p0), '
                             'custom_call_target="tpu_custom_call"\n')
        assert lint_hlo(with_kernel, spec, backend="tpu") == []


class TestBudgets:
    def _budget_roundtrip(self, tmp_path, hlo):
        budget = make_budget(hlo, "t", tolerance=0.25)
        write_budget(budget, str(tmp_path))
        return budget

    def test_roundtrip_passes_on_same_program(self, tmp_path):
        hlo = _golden("start_done_pair.hlo")
        self._budget_roundtrip(tmp_path, hlo)
        spec = HloCheckSpec(name="t")
        assert lint_hlo(hlo, spec, backend="cpu",
                        budget_dir=str(tmp_path)) == []
        on_disk = json.loads(
            (tmp_path / "t.json").read_text(encoding="utf-8"))
        assert on_disk["collective_counts"] == {"all-gather": 1,
                                                "all-reduce": 1,
                                                "collective-permute": 1}

    def test_missing_budget_is_error(self):
        hlo = _golden("start_done_pair.hlo")
        findings = lint_hlo(hlo, HloCheckSpec(name="nope"), backend="cpu",
                            budget_dir="/nonexistent")
        assert [f.rule for f in findings] == ["hlo-budget-missing"]

    def test_bytes_overshoot_beyond_tolerance(self, tmp_path):
        hlo = _golden("start_done_pair.hlo")
        self._budget_roundtrip(tmp_path, hlo)
        # 4 extra all-reduces: counts x5 and bytes x5 >> 25% tolerance
        bloated = hlo + 4 * ("  %arX = f32[8,128]{1,0} all-reduce(%p0), "
                             "to_apply=%add\n")
        findings = lint_hlo(bloated, HloCheckSpec(name="t"), backend="cpu",
                            budget_dir=str(tmp_path))
        rules = {f.rule for f in findings}
        assert "hlo-collective-count-budget" in rules
        assert "hlo-collective-bytes-budget" in rules
        assert all(f.severity == ERROR for f in findings)

    def test_new_collective_kind_is_error(self, tmp_path):
        hlo = _golden("start_done_pair.hlo")
        self._budget_roundtrip(tmp_path, hlo)
        grown = hlo + ("  %a2a = f32[8,128]{1,0} all-to-all(%p0), "
                       "dimensions={0}\n")
        findings = lint_hlo(grown, HloCheckSpec(name="t"), backend="cpu",
                            budget_dir=str(tmp_path))
        assert any(f.rule == "hlo-collective-count-budget"
                   and "all-to-all" in f.location for f in findings)

    def test_large_undershoot_is_warning_not_error(self, tmp_path):
        hlo = _golden("start_done_pair.hlo")
        self._budget_roundtrip(tmp_path, hlo)
        # drop the all-gather pair AND the permute: way under budget
        # (past tolerance + slack) -> stale-budget warning, not an error
        kept = "\n".join(l for l in hlo.splitlines()
                         if "all-gather" not in l and "permute" not in l)
        findings = lint_hlo(kept, HloCheckSpec(name="t"), backend="cpu",
                            budget_dir=str(tmp_path))
        assert [f.severity for f in findings] == [WARNING]
        assert "--update-budgets" in findings[0].message


class TestExactAndAliasedBudgets:
    """``HloCheckSpec(exact=True)`` (the telemetry-off "adds nothing"
    invariant) and ``budget_name`` (check another target's budget)."""

    def _write_ref(self, tmp_path, hlo, name="ref"):
        write_budget(make_budget(hlo, name, tolerance=0.25), str(tmp_path))

    def test_exact_passes_on_identical_program(self, tmp_path):
        hlo = _golden("start_done_pair.hlo")
        self._write_ref(tmp_path, hlo)
        spec = HloCheckSpec(name="off_variant", budget_name="ref", exact=True)
        assert lint_hlo(hlo, spec, backend="cpu",
                        budget_dir=str(tmp_path)) == []

    def test_exact_fails_inside_tolerance_band(self, tmp_path):
        """A bytes drift the tolerant check would wave through (12.5% <
        25%) must fail the exact check — that is the whole point."""
        hlo = _golden("start_done_pair.hlo")
        self._write_ref(tmp_path, hlo)
        drifted = hlo.replace("%ar = f32[8,128]", "%ar = f32[9,128]")
        assert drifted != hlo
        tolerant = lint_hlo(drifted, HloCheckSpec(name="ref"), backend="cpu",
                            budget_dir=str(tmp_path))
        assert [f.rule for f in tolerant] == []
        exact = lint_hlo(drifted,
                         HloCheckSpec(name="off", budget_name="ref",
                                      exact=True),
                         backend="cpu", budget_dir=str(tmp_path))
        assert [f.rule for f in exact] == ["hlo-collective-bytes-budget"]
        assert exact[0].severity == ERROR
        assert "byte-identical" in exact[0].message

    def test_exact_fails_on_one_extra_collective(self, tmp_path):
        hlo = _golden("start_done_pair.hlo")
        self._write_ref(tmp_path, hlo)
        grown = hlo + ("  %ar2 = f32[8,128]{1,0} all-reduce(%p0), "
                       "to_apply=%add\n")
        findings = lint_hlo(grown,
                            HloCheckSpec(name="off", budget_name="ref",
                                         exact=True),
                            backend="cpu", budget_dir=str(tmp_path))
        rules = sorted(f.rule for f in findings)
        assert rules == ["hlo-collective-bytes-budget",
                         "hlo-collective-count-budget"]
        assert all(f.severity == ERROR for f in findings)

    def test_exact_fails_on_missing_collective_kind(self, tmp_path):
        """Undershoot is a WARNING in tolerant mode; exact mode errors in
        both directions."""
        hlo = _golden("start_done_pair.hlo")
        self._write_ref(tmp_path, hlo)
        kept = "\n".join(l for l in hlo.splitlines() if "permute" not in l)
        findings = lint_hlo(kept,
                            HloCheckSpec(name="off", budget_name="ref",
                                         exact=True),
                            backend="cpu", budget_dir=str(tmp_path))
        assert findings and all(f.severity == ERROR for f in findings)
        assert any("collective-permute" in f.location for f in findings)

    def test_missing_referenced_budget_names_the_reference(self, tmp_path):
        hlo = _golden("start_done_pair.hlo")
        findings = lint_hlo(hlo,
                            HloCheckSpec(name="off", budget_name="ref",
                                         exact=True),
                            backend="cpu", budget_dir=str(tmp_path))
        assert [f.rule for f in findings] == ["hlo-budget-missing"]
        assert "ref.json" in findings[0].location


# =========================================================== AST rules
class TestPrngReuse:
    def test_reused_sampler_key_flagged(self):
        src = ("import jax\n"
               "def f(key):\n"
               "    a = jax.random.normal(key, (4,))\n"
               "    b = jax.random.uniform(key, (4,))\n"
               "    return a + b\n")
        findings = lint_source(src, "m.py")
        assert [f.rule for f in findings] == ["ast-prng-reuse"]
        assert "m.py:4" in findings[0].location

    def test_reuse_via_key_kwarg_flagged(self):
        # the CrossDeviceSim / ByzantineWorkers bug shape: attack and
        # aggregator sharing one key via key= kwargs
        src = ("def step(self, key):\n"
               "    sent = self.attack(m, key=key)\n"
               "    agg = self.aggregator(sent, key=key)\n"
               "    return agg\n")
        findings = lint_source(src, "m.py")
        assert [f.rule for f in findings] == ["ast-prng-reuse"]

    def test_split_between_uses_is_clean(self):
        src = ("import jax\n"
               "def f(key):\n"
               "    k1, key = jax.random.split(key)\n"
               "    a = jax.random.normal(k1, (4,))\n"
               "    k2, key = jax.random.split(key)\n"
               "    b = jax.random.normal(k2, (4,))\n"
               "    return a + b\n")
        assert lint_source(src, "m.py") == []

    def test_if_else_branches_do_not_cross_contaminate(self):
        src = ("import jax\n"
               "def f(key, flag):\n"
               "    if flag:\n"
               "        return jax.random.normal(key, (4,))\n"
               "    else:\n"
               "        return jax.random.uniform(key, (4,))\n")
        assert lint_source(src, "m.py") == []

    def test_nested_function_scopes_are_independent(self):
        # a shadowing parameter named `key` in a nested def must not be
        # confused with the outer key (the moe.py false-positive shape)
        src = ("import jax\n"
               "def outer(key):\n"
               "    a = jax.random.normal(key, (4,))\n"
               "    def inner(key):\n"
               "        return jax.random.normal(key, (4,))\n"
               "    return a, inner\n")
        assert lint_source(src, "m.py") == []

    def test_split_indexed_keys_tracked_separately(self):
        src = ("import jax\n"
               "def f(key):\n"
               "    ks = jax.random.split(key, 2)\n"
               "    a = jax.random.normal(ks[0], (4,))\n"
               "    b = jax.random.normal(ks[1], (4,))\n"
               "    c = jax.random.normal(ks[0], (4,))\n"
               "    return a + b + c\n")
        findings = lint_source(src, "m.py")
        assert [f.rule for f in findings] == ["ast-prng-reuse"]
        assert "m.py:6" in findings[0].location


class TestEnvMutation:
    def test_module_level_environ_assign_flagged(self):
        src = ('import os\n'
               'os.environ["XLA_FLAGS"] = "--xla_force_host"\n')
        findings = lint_source(src, "m.py")
        assert [f.rule for f in findings] == ["ast-import-env-mutation"]

    def test_jax_config_update_at_import_flagged(self):
        src = ('import jax\n'
               'jax.config.update("jax_enable_x64", True)\n')
        findings = lint_source(src, "m.py")
        assert [f.rule for f in findings] == ["ast-import-env-mutation"]

    def test_inside_function_is_clean(self):
        src = ('import os\n'
               'def activate():\n'
               '    os.environ["XLA_FLAGS"] = "--xla_force_host"\n')
        assert lint_source(src, "m.py") == []

    def test_under_main_guard_is_clean(self):
        src = ('import os\n'
               'if __name__ == "__main__":\n'
               '    os.environ["XLA_FLAGS"] = "--xla_force_host"\n')
        assert lint_source(src, "m.py") == []

    def test_environ_setdefault_flagged(self):
        src = ('import os\n'
               'os.environ.setdefault("JAX_PLATFORMS", "cpu")\n')
        findings = lint_source(src, "m.py")
        assert [f.rule for f in findings] == ["ast-import-env-mutation"]


class TestMutableDefaultAndSuppression:
    def test_mutable_default_flagged(self):
        findings = lint_source("def f(x, acc=[]):\n    return acc\n", "m.py")
        assert [f.rule for f in findings] == ["ast-mutable-default"]

    def test_none_default_clean(self):
        assert lint_source("def f(x, acc=None):\n    return acc\n",
                           "m.py") == []

    def test_inline_suppression(self):
        src = ("def f(x, acc=[]):  # lint: disable=ast-mutable-default\n"
               "    return acc\n")
        assert lint_source(src, "m.py") == []

    def test_suppress_all(self):
        src = ('import os\n'
               'os.environ["A"] = "b"  # lint: disable=all\n')
        assert lint_source(src, "m.py") == []

    def test_syntax_error_reported_not_raised(self):
        findings = lint_source("def f(:\n", "m.py")
        assert [f.rule for f in findings] == ["ast-syntax-error"]


def test_repo_src_tree_is_ast_clean():
    """The committed src/ tree must pass the AST layer (the same check CI
    runs): a finding here means a real regression or a missing inline
    suppression with justification."""
    findings = lint_paths([os.path.join(REPO, "src")])
    assert findings == [], "\n".join(str(f) for f in findings)


# ============================================================ findings
def test_report_json_and_exit_semantics():
    r = Report(meta={"layers": ["ast"]})
    assert r.ok
    r.extend([Finding(rule="x", severity=WARNING, target="t", location="l",
                      message="m")])
    assert r.ok  # warnings do not gate
    r.extend([Finding(rule="y", severity=ERROR, target="t", location="l",
                      message="m")])
    assert not r.ok
    d = json.loads(r.to_json())
    assert d["n_errors"] == 1 and d["n_warnings"] == 1 and d["ok"] is False
    assert "FAIL" in r.summary()


# ========================================================= jaxpr rules
class TestJaxprLint:
    def test_pallas_call_detected_through_subjaxprs(self):
        import jax
        import jax.numpy as jnp

        from repro.analysis.jaxpr_lint import lint_jaxpr, primitive_counts
        from repro.kernels.ops import gram

        def f(x):
            return gram(x, block_d=128)

        x = jnp.ones((4, 256), jnp.float32)
        jaxpr = jax.make_jaxpr(f)(x)
        assert primitive_counts(jaxpr).get("pallas_call", 0) >= 1
        assert lint_jaxpr(jaxpr, "t", expect_pallas=True) == []

    def test_missing_pallas_flagged(self):
        import jax
        import jax.numpy as jnp

        from repro.analysis.jaxpr_lint import lint_jaxpr

        jaxpr = jax.make_jaxpr(lambda x: x @ x.T)(jnp.ones((4, 8)))
        findings = lint_jaxpr(jaxpr, "t", expect_pallas=True)
        assert [f.rule for f in findings] == ["jaxpr-pallas-missing"]
        assert lint_jaxpr(jaxpr, "t", expect_pallas=False) == []

    def test_callback_flagged(self):
        import jax
        import jax.numpy as jnp
        import numpy as np

        from repro.analysis.jaxpr_lint import lint_jaxpr

        def f(x):
            return jax.pure_callback(
                lambda v: np.asarray(v) * 2,
                jax.ShapeDtypeStruct(x.shape, x.dtype), x)

        jaxpr = jax.make_jaxpr(f)(jnp.ones((4,)))
        findings = lint_jaxpr(jaxpr, "t")
        assert any(f.rule == "jaxpr-callback" for f in findings)


# ========================================================== CLI plumbing
def test_cli_ast_layer_exits_zero_on_repo():
    """`python -m repro.analysis --layers ast` is the cheap half of the CI
    gate: it must exit 0 on the committed tree (no jax import needed)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--layers", "ast"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout


def test_cli_ast_layer_exits_nonzero_on_violation(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text('import os\nos.environ["X"] = "y"\n', encoding="utf-8")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--layers", "ast",
         "--src", str(bad), "--json", str(tmp_path / "report.json")],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    report = json.loads((tmp_path / "report.json").read_text())
    assert report["ok"] is False
    assert report["findings"][0]["rule"] == "ast-import-env-mutation"


def test_dryrun_import_has_no_env_side_effect():
    """Satellite regression test: importing repro.launch.dryrun must not
    mutate XLA_FLAGS (the flag moves behind dryrun.activate())."""
    code = ("import os, sys\n"
            "before = os.environ.get('XLA_FLAGS')\n"
            "import repro.launch.dryrun as d\n"
            "assert os.environ.get('XLA_FLAGS') == before, 'import mutated'\n"
            "assert callable(d.activate)\n"
            "print('clean')\n")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout


def test_budget_files_committed_for_all_targets():
    """Every analysis target must have a committed budget file — except the
    cross-referencing targets (BUDGET_ALIASES), which check another
    target's budget and never own a file."""
    from repro.analysis.hlo_lint import BUDGET_DIR
    from repro.analysis.targets import BUDGET_ALIASES, TARGET_NAMES

    for name in TARGET_NAMES:
        owner = BUDGET_ALIASES.get(name, name)
        path = os.path.join(BUDGET_DIR, f"{owner}.json")
        assert os.path.exists(path), f"missing committed budget {path}"
        budget = json.loads(open(path, encoding="utf-8").read())
        assert budget["target"] == owner
        assert budget["collective_counts"], name
    # an aliased target must never grow its own budget file (it would be
    # dead: lint_hlo always resolves budget_name first)
    for name in BUDGET_ALIASES:
        assert name in TARGET_NAMES, name
        assert not os.path.exists(os.path.join(BUDGET_DIR, f"{name}.json"))
