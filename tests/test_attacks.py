"""Attack implementations (§3.2, §6.2, App. B)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attacks import (
    ALIE,
    IPM,
    BitFlipping,
    Mimic,
    MimicFixed,
    NoAttack,
    alie_z,
    get_attack,
    good_mean,
    good_std,
)


def _setup(key, n=10, f=3, d=16):
    xs = jax.random.normal(key, (n, d))
    mask = jnp.arange(n) < f
    return xs, mask


def test_no_attack_identity(key):
    xs, mask = _setup(key)
    out, _ = NoAttack()(xs, mask)
    np.testing.assert_array_equal(out, xs)


def test_bitflip_negates_byzantine_rows(key):
    xs, mask = _setup(key)
    out, _ = BitFlipping()(xs, mask)
    np.testing.assert_array_equal(out[:3], -xs[:3])
    np.testing.assert_array_equal(out[3:], xs[3:])


def test_ipm_sends_scaled_negative_good_mean(key):
    xs, mask = _setup(key)
    out, _ = IPM(eps=0.5)(xs, mask)
    gm = jnp.mean(xs[3:], axis=0)
    np.testing.assert_allclose(out[0], -0.5 * gm, rtol=1e-5, atol=1e-6)
    # inner product with the good mean is negative (the attack's signature)
    assert float(out[0] @ gm) < 0


def test_alie_stays_within_sigma_band(key):
    xs, mask = _setup(key, n=25, f=5)
    z = alie_z(25, 5)
    assert 0.0 < z < 1.0  # paper: z ~= 0.25 for n=25, f=5
    assert abs(z - 0.25) < 0.15
    out, _ = ALIE(n=25, f=5)(xs, mask)
    mu, sd = good_mean(xs, mask), good_std(xs, mask)
    np.testing.assert_allclose(out[0], mu - z * sd, rtol=1e-4, atol=1e-5)


def test_mimic_fixed_copies_target(key):
    xs, mask = _setup(key)
    out, _ = MimicFixed(i_star=5)(xs, mask)
    for i in range(3):
        np.testing.assert_array_equal(out[i], xs[5])


def test_mimic_copies_a_good_worker(key):
    n, f, d = 10, 3, 16
    attack = Mimic(warmup_steps=5)
    state = attack.init_state(n, d)
    mask = jnp.arange(n) < f
    for t in range(8):
        xs = jax.random.normal(jax.random.fold_in(key, t), (n, d))
        out, state = attack(xs, mask, state)
        i_star = int(state.i_star)
        assert i_star >= f  # always mimics a *good* worker
        for i in range(f):
            np.testing.assert_array_equal(out[i], xs[i_star])
    # after warmup the target is frozen
    frozen = int(state.i_star)
    xs = jax.random.normal(jax.random.fold_in(key, 99), (n, d))
    _, state = attack(xs, mask, state)
    assert int(state.i_star) == frozen


def test_mimic_oja_finds_max_variance_direction(key):
    """The streaming z estimate aligns with the dominant eigvector."""
    n, f, d = 12, 2, 24
    attack = Mimic(warmup_steps=100)
    state = attack.init_state(n, d)
    mask = jnp.arange(n) < f
    direction = jax.nn.one_hot(3, d)  # variance concentrated on coord 3
    for t in range(60):
        k = jax.random.fold_in(key, t)
        coef = jax.random.normal(k, (n, 1)) * 5.0
        xs = coef * direction + 0.1 * jax.random.normal(jax.random.fold_in(k, 1), (n, d))
        _, state = attack(xs, mask, state)
    cos = abs(float(state.z @ direction))
    assert cos > 0.9, cos


def test_registry(key):
    assert isinstance(get_attack("bf"), BitFlipping)
    assert isinstance(get_attack("ipm", eps=0.2), IPM)
    with pytest.raises(KeyError):
        get_attack("nope")
    with pytest.raises(ValueError):
        ALIE()  # needs z or (n, f)
