"""Per-architecture smoke tests (assignment deliverable f).

Each of the 10 assigned architectures is instantiated as a REDUCED variant
of the same family (2 layers, d_model <= 512, <= 4 experts) and runs one
forward + train-grad + decode step on CPU, asserting output shapes and the
absence of NaNs. The FULL configs are exercised by the dry-run only.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs, smoke_config
from repro.models import transformer as tfm

ARCHS = list_archs()


def _batch(cfg, B=2, S=32, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    shape = (B, cfg.n_codebooks, S) if cfg.n_codebooks else (B, S)
    toks = jax.random.randint(key, shape, 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.n_prefix_tokens:
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (B, cfg.n_prefix_tokens, cfg.d_model)
        ).astype(jnp.dtype(cfg.dtype))
    return batch


def test_all_archs_assigned():
    assert len(ARCHS) == 10
    assert {get_config(a).family for a in ARCHS} == {
        "dense", "moe", "ssm", "hybrid", "vlm", "audio",
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    expected = {
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (arch, got, expected)
    assert cfg.source  # every config cites its source


def test_assignment_details():
    assert get_config("olmoe-1b-7b").n_experts == 64
    assert get_config("olmoe-1b-7b").experts_per_token == 8
    assert get_config("kimi-k2-1t-a32b").n_experts == 384
    assert get_config("kimi-k2-1t-a32b").experts_per_token == 8
    assert get_config("jamba-v0.1-52b").n_experts == 16
    assert get_config("jamba-v0.1-52b").experts_per_token == 2
    assert get_config("mamba2-130m").ssm_state == 128
    assert get_config("gemma-7b").head_dim_ == 256
    assert get_config("gemma-7b").mlp_kind == "geglu"
    assert get_config("qwen1.5-32b").qkv_bias
    assert get_config("qwen2.5-14b").qkv_bias
    assert get_config("musicgen-medium").n_codebooks == 4
    # jamba: attn:ssm = 1:7 interleave
    pattern = get_config("jamba-v0.1-52b").pattern_
    assert len(pattern) == 8
    assert sum(1 for m, _ in pattern if m == "attn") == 1
    assert sum(1 for m, _ in pattern if m == "ssm") == 7


def test_param_counts_plausible():
    """Analytic param counts are in the right ballpark for the names."""
    expect = {
        "tinyllama-1.1b": (0.9e9, 1.4e9),
        "qwen2.5-14b": (12e9, 17e9),
        "qwen1.5-32b": (28e9, 37e9),
        "gemma-7b": (7e9, 10e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "olmoe-1b-7b": (5e9, 8e9),
        "kimi-k2-1t-a32b": (0.8e12, 1.3e12),
        "jamba-v0.1-52b": (40e9, 60e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, f"{n:.3e}")
    # MoE active counts are far below total
    assert get_config("kimi-k2-1t-a32b").active_param_count() < 0.1 * \
        get_config("kimi-k2-1t-a32b").param_count()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = smoke_config(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    B, S = 2, 32

    logits, aux = tfm.forward(params, cfg, batch["tokens"],
                              prefix_embeds=batch.get("prefix_embeds"))
    if cfg.n_codebooks:
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    loss, aux = tfm.loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss))

    grads = jax.grad(lambda p: tfm.loss_fn(p, cfg, batch)[0])(params)
    for leaf in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = smoke_config(arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    B, L = 2, 64
    cache = tfm.init_cache(cfg, B, L)
    tok = jnp.zeros((B, cfg.n_codebooks) if cfg.n_codebooks else (B,), jnp.int32)
    logits, new_cache = tfm.decode_step(params, cfg, cache, tok,
                                        jnp.asarray(0, jnp.int32))
    if cfg.n_codebooks:
        assert logits.shape == (B, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree_util.tree_structure(new_cache) == \
        jax.tree_util.tree_structure(cache)


def test_decode_matches_forward_dense():
    """Token-by-token decode reproduces the teacher-forced forward logits."""
    cfg = smoke_config("tinyllama-1.1b")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full_logits, _ = tfm.forward(params, cfg, toks, positions=None)

    cache = tfm.init_cache(cfg, B, S)
    for t in range(S):
        step_logits, cache = tfm.decode_step(
            params, cfg, cache, toks[:, t], jnp.asarray(t, jnp.int32)
        )
        assert jnp.allclose(step_logits, full_logits[:, t], rtol=2e-3, atol=2e-3), t


def test_decode_matches_forward_ssm():
    """Recurrent decode == chunked-scan train path for the SSM family."""
    cfg = smoke_config("mamba2-130m")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full_logits, _ = tfm.forward(params, cfg, toks)

    cache = tfm.init_cache(cfg, B, S)
    for t in range(S):
        step_logits, cache = tfm.decode_step(
            params, cfg, cache, toks[:, t], jnp.asarray(t, jnp.int32)
        )
        assert jnp.allclose(step_logits, full_logits[:, t], rtol=5e-3, atol=5e-3), t
