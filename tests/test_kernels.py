"""Per-kernel allclose vs the pure-jnp oracles (ref.py), interpret mode.

Sweeps worker counts, parameter dims (aligned and ragged), block sizes and
dtypes per the assignment's kernel-validation requirement.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    bucket_mix,
    cclip_combine,
    cwise_median,
    cwise_trimmed_mean,
    pairwise_gram,
    residual_norms,
)
from repro.kernels import ops, ref

SHAPES = [(4, 128), (10, 1000), (25, 4097), (53, 257), (64, 8192), (7, 64)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _xs(shape, dtype, seed=0):
    return (jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * 3).astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_pairwise_gram(shape, dtype):
    xs = _xs(shape, dtype)
    tol = dict(rtol=1e-5, atol=1e-3) if dtype == jnp.float32 else dict(rtol=3e-2, atol=1.0)
    np.testing.assert_allclose(pairwise_gram(xs), ref.pairwise_gram(xs), **tol)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_cwise_median(shape, dtype):
    xs = _xs(shape, dtype)
    np.testing.assert_allclose(
        cwise_median(xs), ref.cwise_median(xs), rtol=1e-6, atol=1e-6
    )


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_cwise_trimmed_mean(shape, dtype):
    W, d = shape
    xs = _xs(shape, dtype)
    for n_trim in sorted({0, 1, (W - 1) // 2}):
        np.testing.assert_allclose(
            cwise_trimmed_mean(xs, n_trim), ref.cwise_trimmed_mean(xs, n_trim),
            rtol=1e-6, atol=1e-6,
        )


def test_cwise_trimmed_mean_rejects_empty_band():
    xs = _xs((4, 128), jnp.float32)
    with pytest.raises(ValueError):
        cwise_trimmed_mean(xs, 2)  # band [2, 2) would be empty


@pytest.mark.parametrize("shape", SHAPES)
def test_bucket_mix(shape):
    W, d = shape
    xs = _xs(shape, jnp.float32)
    m = jax.random.uniform(jax.random.PRNGKey(1), (max(1, W // 2), W))
    m = m / m.sum(1, keepdims=True)
    np.testing.assert_allclose(
        bucket_mix(m, xs), ref.bucket_mix(m, xs), rtol=1e-5, atol=1e-4
    )


@pytest.mark.parametrize("shape", SHAPES)
def test_residual_norms(shape):
    W, d = shape
    xs = _xs(shape, jnp.float32)
    c = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(2), (W,)))
    np.testing.assert_allclose(
        residual_norms(xs, c), ref.residual_norms(xs, c), rtol=1e-4, atol=1e-3
    )


@pytest.mark.parametrize("shape", SHAPES)
def test_cclip_combine(shape):
    W, d = shape
    xs = _xs(shape, jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(3), (d,))
    lam = jax.random.uniform(jax.random.PRNGKey(4), (W,))
    np.testing.assert_allclose(
        cclip_combine(xs, v, lam), ref.cclip_combine(xs, v, lam), rtol=1e-5, atol=1e-4
    )


@pytest.mark.parametrize("block_d", [128, 512, 4096])
def test_block_size_invariance(block_d):
    """Results must not depend on the BlockSpec tiling."""
    xs = _xs((16, 3000), jnp.float32)
    np.testing.assert_allclose(
        pairwise_gram(xs, block_d=block_d), ref.pairwise_gram(xs), rtol=1e-5, atol=1e-3
    )
    np.testing.assert_allclose(
        cwise_median(xs, block_d=block_d), ref.cwise_median(xs), rtol=1e-6, atol=1e-6
    )
    np.testing.assert_allclose(
        cwise_trimmed_mean(xs, 3, block_d=block_d), ref.cwise_trimmed_mean(xs, 3),
        rtol=1e-6, atol=1e-6,
    )


# --------------------------------------------------- composed aggregator ops
def test_ops_rfa_aggregate_matches_ref():
    xs = _xs((21, 1500), jnp.float32)
    np.testing.assert_allclose(
        ops.rfa_aggregate(xs), ref.rfa_aggregate(xs), rtol=1e-4, atol=1e-4
    )


def test_ops_cclip_aggregate_matches_ref():
    xs = _xs((15, 900), jnp.float32)
    np.testing.assert_allclose(
        ops.cclip_aggregate(xs, 5.0), ref.cclip_aggregate(xs, 5.0), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("shape", [(10, 1000), (53, 257)])
def test_residual_norms_explicit_center(shape):
    """center=v is the pseudo-row-free path: ||x_i - v||^2 without building
    a [W+1, d] stack."""
    W, d = shape
    xs = _xs(shape, jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(5), (d,), jnp.float32)
    expect = jnp.sum((xs - v[None, :]) ** 2, axis=1)
    np.testing.assert_allclose(
        residual_norms(xs, center=v), expect, rtol=1e-4, atol=1e-3
    )
    with pytest.raises(ValueError):
        residual_norms(xs)
    with pytest.raises(ValueError):
        c = jnp.full((W,), 1.0 / W, jnp.float32)
        residual_norms(xs, c, center=v)


@pytest.mark.parametrize("shape", [(10, 1000), (25, 4097)])
def test_cclip_fused_iter_matches_two_pass(shape):
    """Fused kernel == separate combine + residual-norm passes."""
    from repro.kernels import cclip_fused_iter

    W, d = shape
    xs = _xs(shape, jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(6), (d,), jnp.float32)
    lam = jax.random.uniform(jax.random.PRNGKey(7), (W,))
    v_new, r2 = cclip_fused_iter(xs, v, lam)
    expect_v = ref.cclip_combine(xs, v, lam)
    np.testing.assert_allclose(v_new, expect_v, rtol=1e-5, atol=1e-4)
    expect_r2 = jnp.sum((xs - expect_v[None, :]) ** 2, axis=1)
    np.testing.assert_allclose(r2, expect_r2, rtol=1e-4, atol=1e-3)


def test_gram_acc_chaining_bit_exact():
    """Chained per-segment Gram calls (acc + full_blocks) == one call on the
    concatenated block-aligned buffer, BIT for bit — the packed/per-leaf
    bridge."""
    bd = 256
    xs1 = _xs((12, bd * 2), jnp.float32, seed=11)
    xs2 = _xs((12, bd * 3), jnp.float32, seed=12)
    chained = pairwise_gram(xs1, block_d=bd, full_blocks=True)
    chained = pairwise_gram(xs2, chained, block_d=bd, full_blocks=True)
    packed = pairwise_gram(jnp.concatenate([xs1, xs2], axis=1), block_d=bd)
    np.testing.assert_array_equal(np.asarray(chained), np.asarray(packed))


def test_ops_match_core_aggregators(key):
    """Kernel path == the repro.core implementations used by the trainer."""
    from repro.core.aggregators import RFA, CenteredClip, CoordinateWiseMedian

    xs = jax.random.normal(key, (13, 700)) * 2
    np.testing.assert_allclose(
        ops.cm_aggregate(xs), CoordinateWiseMedian().aggregate(xs), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        ops.rfa_aggregate(xs, n_iters=8), RFA(n_iters=8).aggregate(xs),
        rtol=1e-4, atol=1e-4,
    )
    np.testing.assert_allclose(
        ops.cclip_aggregate(xs, 3.0, n_iters=3),
        CenteredClip(tau=3.0, n_iters=3).aggregate(xs),
        rtol=1e-4, atol=1e-4,
    )


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("Sq,Skv,H,KV,window", [
    (64, 64, 4, 4, 0),       # MHA causal
    (64, 64, 8, 2, 0),       # GQA
    (64, 64, 4, 2, 24),      # sliding window
    (32, 128, 4, 4, 0),      # chunked prefill (q suffix of kv)
])
def test_flash_attention_matches_ref(Sq, Skv, H, KV, window):
    from repro.kernels.flash_attention import flash_attention
    key = jax.random.PRNGKey(0)
    B, dh = 2, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, Skv, KV, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, Skv, KV, dh), jnp.float32)
    out = flash_attention(q, k, v, window=window, block_q=16, block_kv=32)
    expect = ref.attention(q, k, v, window=window)
    np.testing.assert_allclose(out, expect, rtol=2e-4, atol=2e-4)


def test_flash_attention_matches_model_blockwise():
    """Kernel == the pure-JAX blockwise impl used by the models layer."""
    from repro.kernels.flash_attention import flash_attention
    from repro.models.attention import _attn_blockwise
    key = jax.random.PRNGKey(1)
    B, S, H, KV, dh = 1, 64, 4, 2, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, dh), jnp.float32)
    out_kernel = flash_attention(q, k, v, block_q=16, block_kv=16)
    out_blockwise = _attn_blockwise(q, k, v, dh ** -0.5, True, 0, 16, 16)
    np.testing.assert_allclose(out_kernel, out_blockwise, rtol=2e-4, atol=2e-4)
