"""Serving engine: continuous batching over decode_step.

Correctness bar: every request served through the multi-slot engine must
produce EXACTLY the tokens a sequential single-request greedy decode
produces (slot reuse and mixed-position cohorts must not leak state)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models import transformer as tfm
from repro.serving import Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("tinyllama-1.1b")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def reference_decode(cfg, params, prompt, max_new):
    """Sequential single-request greedy decode (B=1)."""
    cache = tfm.init_cache(cfg, 1, 256)
    out = []
    tok = None
    for t in range(len(prompt) + max_new - 1):
        feed = prompt[t] if t < len(prompt) else out[-1]
        logits, cache = tfm.decode_step(
            params, cfg, cache, jnp.asarray([feed], jnp.int32),
            jnp.asarray(t, jnp.int32))
        if t >= len(prompt) - 1:
            out.append(int(jnp.argmax(logits[0])))
    return out[:max_new]


def test_single_request_matches_reference(setup):
    cfg, params = setup
    prompt = [5, 17, 99, 3]
    expect = reference_decode(cfg, params, prompt, 6)
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64)
    eng.submit(Request(uid=1, prompt=prompt, max_new_tokens=6))
    done = eng.run_until_drained()
    assert done[1].output == expect


def test_batch_of_heterogeneous_requests(setup):
    cfg, params = setup
    prompts = {
        1: [5, 17, 99, 3],
        2: [42],
        3: [7, 7, 7, 7, 7, 7, 7, 7],
        4: [100, 200],
        5: [11, 12, 13],
    }
    news = {1: 4, 2: 6, 3: 3, 4: 5, 5: 4}
    expect = {u: reference_decode(cfg, params, p, news[u])
              for u, p in prompts.items()}

    # 2 slots for 5 requests => forced slot reuse (continuous batching)
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64)
    for u, p in prompts.items():
        eng.submit(Request(uid=u, prompt=p, max_new_tokens=news[u]))
    done = eng.run_until_drained()
    assert set(done) == set(prompts)
    for u in prompts:
        assert done[u].output == expect[u], (u, done[u].output, expect[u])


def test_eos_early_stop(setup):
    cfg, params = setup
    prompt = [5, 17, 99, 3]
    full = reference_decode(cfg, params, prompt, 8)
    # pick an eos token at its FIRST occurrence in the greedy stream
    j = next(i for i, t in enumerate(full) if t not in full[:i])
    eos = full[j]
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=64)
    eng.submit(Request(uid=9, prompt=prompt, max_new_tokens=8, eos_id=eos))
    done = eng.run_until_drained()
    assert done[9].output == full[:j + 1]


def test_ssm_arch_served(setup):
    """Recurrent-state archs need the explicit slot reset — verify reuse."""
    cfg = smoke_config("mamba2-130m")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    p1, p2 = [3, 1, 4, 1, 5], [2, 7, 1, 8]
    e1 = reference_decode(cfg, params, p1, 4)
    e2 = reference_decode(cfg, params, p2, 4)
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=64)  # serial reuse
    eng.submit(Request(uid=1, prompt=p1, max_new_tokens=4))
    eng.submit(Request(uid=2, prompt=p2, max_new_tokens=4))
    done = eng.run_until_drained()
    assert done[1].output == e1
    assert done[2].output == e2
