"""SSD (Mamba-2) scan: chunked dual form == naive recurrence oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import ssm


def naive_ssd(x, dt, A, B_, C_):
    """Direct O(S) recurrence: h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t^T;
    y_t = h_t C_t. Shapes as ssd_scan."""
    Bsz, S, H, P = x.shape
    N = B_.shape[-1]
    Bh = B_[:, :, 0]  # [B,S,N] (G=1)
    Ch = C_[:, :, 0]
    h = jnp.zeros((Bsz, H, P, N), jnp.float32)
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A[None, :])  # [B,H]
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, t], x[:, t].astype(jnp.float32),
                         Bh[:, t].astype(jnp.float32))
        h = h * dA[..., None, None] + upd
        ys.append(jnp.einsum("bhpn,bn->bhp", h, Ch[:, t].astype(jnp.float32)))
    return jnp.stack(ys, axis=1), h  # [B,S,H,P], [B,H,P,N]


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_scan_matches_naive_recurrence(key, chunk):
    Bsz, S, H, P, N = 2, 16, 3, 4, 8
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (Bsz, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B_ = jax.random.normal(ks[3], (Bsz, S, 1, N))
    C_ = jax.random.normal(jax.random.fold_in(key, 9), (Bsz, S, 1, N))

    y_chunk, h_chunk = ssm.ssd_scan(x, dt, A, B_, C_, chunk)
    y_naive, h_naive = naive_ssd(x, dt, A, B_, C_)
    np.testing.assert_allclose(y_chunk, y_naive, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h_chunk, h_naive, rtol=1e-4, atol=1e-4)


def test_ssd_scan_chunk_invariance(key):
    """Different chunk sizes give identical results."""
    Bsz, S, H, P, N = 1, 32, 2, 4, 4
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (Bsz, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B_ = jax.random.normal(ks[3], (Bsz, S, 1, N))
    C_ = jax.random.normal(ks[4], (Bsz, S, 1, N))
    y4, _ = ssm.ssd_scan(x, dt, A, B_, C_, 4)
    y32, _ = ssm.ssd_scan(x, dt, A, B_, C_, 32)
    np.testing.assert_allclose(y4, y32, rtol=1e-4, atol=1e-4)


def test_segsum_exp_structure():
    da = jnp.asarray([[0.1, -0.2, 0.3]])
    L = ssm._segsum_exp(da)[0]
    assert L.shape == (3, 3)
    # strictly upper triangle is zero; diagonal is exp(0)=1
    np.testing.assert_allclose(jnp.diagonal(L), 1.0, rtol=1e-6)
    assert float(L[0, 1]) == 0.0
    # L[2,0] = exp(da_1 + da_2)  (decay from step 0 to 2 excludes da_0)
    np.testing.assert_allclose(L[2, 0], jnp.exp(-0.2 + 0.3), rtol=1e-6)


def test_causal_conv_is_causal(key):
    B, S, C, K = 1, 10, 6, 4
    x = jax.random.normal(key, (B, S, C))
    w = jax.random.normal(jax.random.fold_in(key, 1), (C, K))
    b = jnp.zeros((C,))
    y1 = ssm._causal_conv(x, w, b)
    x2 = x.at[:, -1].set(0.0)
    y2 = ssm._causal_conv(x2, w, b)
    np.testing.assert_allclose(y1[:, :-1], y2[:, :-1], rtol=1e-5, atol=1e-6)


def test_ssm_layer_decode_matches_train(key):
    """Layer-level: step-by-step decode equals the chunked train path."""
    cfg = smoke_config("mamba2-130m")
    cfg = dataclasses.replace(cfg, dtype="float32")
    p = ssm.init_ssm(key, cfg)
    B, S = 1, 12
    h = jax.random.normal(jax.random.fold_in(key, 2), (B, S, cfg.d_model)) * 0.3
    full = ssm.ssm_layer(p, h, cfg)

    cache = ssm.init_ssm_cache(B, cfg, jnp.float32)
    for t in range(S):
        out, cache = ssm.decode_ssm(p, h[:, t:t + 1], cache, cfg)
        np.testing.assert_allclose(out[:, 0], full[:, t], rtol=3e-3, atol=3e-3)


# --------------------------------------------------- hypothesis properties
pytest.importorskip("hypothesis")  # absent in some environments
from hypothesis import given, settings, strategies as st


@given(S=st.sampled_from([8, 16, 24]), H=st.integers(1, 4),
       P=st.sampled_from([2, 4]), N=st.sampled_from([2, 8]),
       seed=st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_ssd_scan_property_matches_naive(S, H, P, N, seed):
    """Chunked SSD == naive recurrence for arbitrary shapes (property)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    Bsz = 1
    x = jax.random.normal(ks[0], (Bsz, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B_ = jax.random.normal(ks[3], (Bsz, S, 1, N))
    C_ = jax.random.normal(ks[4], (Bsz, S, 1, N))
    chunk = 8 if S % 8 == 0 else S
    y_c, h_c = ssm.ssd_scan(x, dt, A, B_, C_, chunk)
    y_n, h_n = naive_ssd(x, dt, A, B_, C_)
    np.testing.assert_allclose(y_c, y_n, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(h_c, h_n, rtol=5e-4, atol=5e-4)
