"""Data pipeline: synthetic task, partitions, long-tail, token streams."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # absent in some environments
from hypothesis import given, settings, strategies as st

from repro.data.partition import (
    long_tail_subsample,
    partition_by_label,
    partition_iid,
    worker_datasets,
)
from repro.data.pipeline import sample_worker_batches
from repro.data.synthetic import make_token_stream, make_train_test


def test_synthetic_task_learnable(key):
    """A linear probe separates the classes => the task is non-trivial."""
    X, Y, Xt, Yt = make_train_test(key, n_train=2000, n_test=500)
    assert X.shape == (2000, 784) and Xt.shape == (500, 784)
    # class-mean classifier accuracy >> chance
    means = jnp.stack([X[Y == c].mean(0) for c in range(10)])
    pred = jnp.argmax(Xt @ means.T, axis=1)
    acc = float(jnp.mean((pred == Yt).astype(jnp.float32)))
    assert acc > 0.8, acc


def test_partition_by_label_is_heterogeneous(key):
    _, Y, _, _ = make_train_test(key, n_train=2000, n_test=100)
    idx = partition_by_label(Y, n_workers=10)
    # each worker sees at most 3 distinct classes (sorted split)
    for row in idx:
        assert len(np.unique(np.asarray(Y)[row])) <= 3


def test_partition_iid_is_homogeneous(key):
    _, Y, _, _ = make_train_test(key, n_train=2000, n_test=100)
    idx = partition_iid(len(Y), n_workers=10)
    for row in idx:
        assert len(np.unique(np.asarray(Y)[row])) == 10


@given(alpha=st.sampled_from([1.0, 10.0, 500.0]))
@settings(max_examples=3, deadline=None)
def test_long_tail_alpha_ratio(alpha):
    key = jax.random.PRNGKey(0)
    X, Y, _, _ = make_train_test(key, n_train=5000, n_test=100)
    Xs, Ys = long_tail_subsample(X, Y, alpha=alpha)
    counts = np.bincount(np.asarray(Ys), minlength=10).astype(float)
    if alpha == 1.0:
        assert counts.max() / counts.min() < 1.5
    else:
        ratio = counts.max() / counts.min()
        assert 0.3 * alpha < ratio < 3 * alpha, (alpha, ratio)


def test_worker_datasets_byzantine_first(key):
    X, Y, _, _ = make_train_test(key, n_train=1000, n_test=100)
    wx, wy = worker_datasets(X, Y, n_good=8, n_byz=2, noniid=True)
    assert wx.shape[0] == 10
    # byzantine rows (0,1) sample the whole dataset => many classes
    assert len(np.unique(wy[0])) >= 5
    # good rows are label-sorted chunks => few classes
    assert len(np.unique(wy[5])) <= 3


def test_sample_worker_batches_shapes(key):
    data_x = jnp.zeros((4, 100, 7))
    data_y = jnp.zeros((4, 100), jnp.int32)
    bx, by = sample_worker_batches(key, data_x, data_y, 16)
    assert bx.shape == (4, 16, 7) and by.shape == (4, 16)


def test_token_stream_heterogeneity(key):
    """Heterogeneous workers follow different bigram laws; homogeneous share
    one. Verify via cross-worker law agreement."""
    toks_het = make_token_stream(key, n_workers=4, seq_len=128,
                                 n_seqs_per_worker=2, vocab=97, noise_p=0.0)
    toks_hom = make_token_stream(key, n_workers=4, seq_len=128,
                                 n_seqs_per_worker=2, vocab=97,
                                 heterogeneous=False, noise_p=0.0)
    assert toks_het.shape == (4, 2, 129)

    def recover_law(seq, V=97):
        """Solve next = (a t + b) mod V from two transitions (V prime)."""
        s = [int(v) for v in np.asarray(seq).reshape(-1)]
        pairs = [(s[i], s[i + 1]) for i in range(len(s) - 1)]
        (t1, u1) = pairs[0]
        (t2, u2) = next(p for p in pairs if p[0] != t1)
        a = ((u1 - u2) * pow(t1 - t2, -1, V)) % V
        b = (u1 - a * t1) % V
        return a, b

    laws_hom = {recover_law(toks_hom[w, 0]) for w in range(4)}
    laws_het = {recover_law(toks_het[w, 0]) for w in range(4)}
    assert len(laws_hom) == 1, laws_hom
    assert len(laws_het) >= 3, laws_het
