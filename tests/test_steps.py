"""Distributed step factories executed on a 1-device mesh (numerics), plus
sharding-rule unit tests. The 256/512-device lowering is covered by the
dry-run (repro.launch.dryrun), which owns the placeholder-device env var."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, smoke_config
from repro.configs.base import ByzConfig, InputShape
from repro.distributed.sharding import batch_spec, infer_param_spec
from repro.distributed.steps import input_specs, make_serve_step, make_train_step
from repro.launch.mesh import make_host_mesh, n_workers
from repro.models import transformer as tfm
from repro.optim import make_optimizer


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh(1, 1)


def _small_shape(kind="train"):
    return InputShape("test", seq_len=32, global_batch=4, kind=kind)


def test_input_specs_train():
    cfg = smoke_config("tinyllama-1.1b")
    specs = input_specs(cfg, INPUT_SHAPES["train_4k"])
    assert specs["tokens"].shape == (256, 4096)
    assert specs["labels"].dtype == jnp.int32


def test_input_specs_vlm_prefix():
    cfg = smoke_config("internvl2-2b")
    specs = input_specs(cfg, INPUT_SHAPES["train_4k"])
    assert "prefix_embeds" in specs
    assert specs["prefix_embeds"].shape[2] == cfg.d_model


def test_input_specs_audio_codebooks():
    cfg = smoke_config("musicgen-medium")
    specs = input_specs(cfg, INPUT_SHAPES["train_4k"])
    assert specs["tokens"].shape == (256, cfg.n_codebooks, 4096)


def test_input_specs_decode():
    cfg = smoke_config("qwen2.5-14b")
    specs = input_specs(cfg, INPUT_SHAPES["decode_32k"])
    assert set(specs) == {"token"}
    assert specs["token"].shape == (128,)


def test_train_step_executes_and_learns(mesh):
    """One real train step on the tiny mesh: loss finite, params move."""
    cfg = smoke_config("tinyllama-1.1b")
    byz = ByzConfig(aggregator="rfa", mixing="bucketing", s=2,
                    worker_momentum=0.9)
    shape = _small_shape()
    with mesh:
        step_fn, sh = make_train_step(cfg, byz, mesh, lr=1e-2)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        opt_init, _ = make_optimizer("sgdm")
        opt_state = opt_init(params)
        worker_m = jax.tree_util.tree_map(
            lambda x: jnp.zeros((n_workers(mesh),) + x.shape, jnp.float32), params
        ) if sh["worker_m"] else {}
        toks = jax.random.randint(jax.random.PRNGKey(1),
                                  (shape.global_batch, shape.seq_len), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        p0 = jax.tree_util.tree_leaves(params)[0].copy()
        params, opt_state, worker_m, metrics = step_fn(
            params, opt_state, worker_m, jax.random.PRNGKey(2), batch)
        assert bool(jnp.isfinite(metrics["loss"]))
        assert not jnp.allclose(jax.tree_util.tree_leaves(params)[0], p0)


def test_train_step_mean_baseline_matches_robust_with_mean(mesh):
    """aggregator=mean + mixing=none takes the fast all-reduce path; its
    gradient equals the robust path with a Mean aggregator."""
    cfg = smoke_config("mamba2-130m")
    shape = _small_shape()
    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (shape.global_batch, shape.seq_len), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    outs = {}
    for name, byz in {
        "fast": ByzConfig(aggregator="mean", mixing="none", worker_momentum=0.0),
        "robust": ByzConfig(aggregator="rfa", mixing="none", worker_momentum=0.0),
    }.items():
        with mesh:
            step_fn, sh = make_train_step(cfg, byz, mesh, lr=1e-2)
            params = tfm.init_params(cfg, jax.random.PRNGKey(0))
            opt_init, _ = make_optimizer("sgdm")
            new_p, *_ , m = step_fn(params, opt_init(params), {},
                                    jax.random.PRNGKey(2), batch)
            outs[name] = new_p
    # with 1 worker, RFA degenerates to that worker's gradient == the mean
    for a, b in zip(jax.tree_util.tree_leaves(outs["fast"]),
                    jax.tree_util.tree_leaves(outs["robust"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=2e-3, atol=2e-3)


def test_sharding_overrides_land_in_train_step(mesh):
    """gemma-7b carries a per-arch sharding override (the ROADMAP hillclimb
    lever): the tied embed is forced to P("data", "model") instead of the
    inferred rule. Assert the override survives the whole config ->
    make_train_step pipeline and actually lands in the step shardings."""
    from repro.distributed.sharding import overrides_from_config, param_shardings

    cfg = smoke_config("gemma-7b")
    assert overrides_from_config(cfg) == {"^embed$": P("data", "model")}

    byz = ByzConfig(aggregator="rfa", mixing="bucketing", s=2)
    with mesh:
        _, sh = make_train_step(cfg, byz, mesh, lr=1e-2)
    assert sh["params"]["embed"].spec == P("data", "model")
    # and it is the override that put it there — the inferred rule differs
    params_shape = jax.eval_shape(lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))
    plain = param_shardings(params_shape, mesh, fsdp=cfg.fsdp)
    assert plain["embed"].spec != sh["params"]["embed"].spec
    # non-override leaves are untouched by the override machinery
    for path in plain:
        if path != "embed":
            same = jax.tree_util.tree_map(lambda a, b: a == b,
                                          plain[path], sh["params"][path])
            assert all(jax.tree_util.tree_leaves(same)), path
    # configs without overrides decode to an empty mapping
    assert overrides_from_config(smoke_config("tinyllama-1.1b")) == {}


def test_serve_step_executes(mesh):
    cfg = smoke_config("qwen2.5-14b")
    shape = InputShape("test_decode", seq_len=64, global_batch=2, kind="decode")
    with mesh:
        serve, cache_shape, cache_sh = make_serve_step(cfg, mesh, shape)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        cache = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), cache_shape)
        tok = jnp.zeros((2,), jnp.int32)
        logits, new_cache = serve(params, cache, tok, jnp.asarray(0, jnp.int32))
        assert logits.shape == (2, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))


# ------------------------------------------------------------ sharding rules
class _FakeMesh:
    def __init__(self, axes):
        self.axis_names = tuple(axes)
        import numpy as _np
        class _D:  # minimal stand-in with .shape
            pass
        self.devices = _D()
        self.devices.shape = tuple(axes.values())


def test_infer_param_spec_model_axis():
    mesh = _FakeMesh({"data": 16, "model": 16})
    spec = infer_param_spec("lm_head", (512, 4096), mesh)
    assert spec == P(None, "model")  # largest divisible dim gets model


def test_infer_param_spec_blocks_skips_period_axis():
    mesh = _FakeMesh({"data": 16, "model": 16})
    spec = infer_param_spec("blocks/0/ff/w_up", (22, 512, 2048), mesh)
    assert spec[0] is None  # scan period axis never sharded
    assert "model" in spec


def test_infer_param_spec_fsdp():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = infer_param_spec("blocks/0/ff/w_up", (22, 8192, 4096), mesh, fsdp=True)
    assert "model" in spec
    assert ("pod", "data") in spec or "data" in spec


def test_batch_spec_worker_axes():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert batch_spec(mesh) == P(("pod", "data"))
    mesh1 = _FakeMesh({"data": 16, "model": 16})
    assert batch_spec(mesh1) == P("data")


def test_prefill_last_only_shapes(mesh):
    """Serving prefill emits only next-token logits (EXPERIMENTS §Perf it. 2)."""
    from repro.distributed.steps import make_prefill_step
    cfg = smoke_config("tinyllama-1.1b")
    with mesh:
        prefill = make_prefill_step(cfg, mesh)  # last_only default
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        toks = jnp.zeros((2, 16), jnp.int32)
        logits = prefill(params, {"tokens": toks})
        assert logits.shape == (2, 1, cfg.vocab_size)
        full = make_prefill_step(cfg, mesh, last_only=False)
        logits_full = full(params, {"tokens": toks})
        assert logits_full.shape == (2, 16, cfg.vocab_size)
        # last_only slice == last position of the full logits
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(logits_full[:, -1]),
                                   rtol=2e-3, atol=2e-3)
