"""Checkpoint save/restore roundtrip."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.training.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def test_roundtrip(tmp_path, key):
    tree = {
        "params": {"w": jax.random.normal(key, (3, 4)),
                   "b": jnp.zeros((4,), jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }
    save_checkpoint(str(tmp_path), 7, tree)
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    restored = restore_checkpoint(str(tmp_path), like)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_step(tmp_path, key):
    tree = {"w": jnp.ones(2)}
    save_checkpoint(str(tmp_path), 10, tree)
    save_checkpoint(str(tmp_path), 200, tree)
    assert latest_step(str(tmp_path)) == 200
    restored = restore_checkpoint(str(tmp_path), tree)  # picks latest
    np.testing.assert_array_equal(restored["w"], tree["w"])


def test_missing_key_raises(tmp_path, key):
    save_checkpoint(str(tmp_path), 0, {"w": jnp.ones(2)})
    try:
        restore_checkpoint(str(tmp_path), {"w": jnp.ones(2), "extra": jnp.ones(1)})
        assert False, "expected KeyError"
    except KeyError:
        pass
