"""Mixing (Algorithm 1): matrix structure, Lemma-1 variance reduction, and
hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # absent in some environments
from hypothesis import given, settings, strategies as st

from repro.core.mixing import Bucketing, FixedGrouping, NoMix, Resampling, get_mixer
from repro.core.theory import pairwise_variance


# --------------------------------------------------------- matrix structure
@given(n=st.integers(2, 40), s=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_bucketing_matrix_row_stochastic(n, s, seed):
    m = Bucketing(s).matrix(jax.random.PRNGKey(seed), n)
    assert m.shape == (int(np.ceil(n / s)), n)
    np.testing.assert_allclose(np.sum(np.asarray(m), axis=1), 1.0, rtol=1e-6)
    # every input lands in exactly one bucket
    col_nonzero = np.sum(np.asarray(m) > 0, axis=0)
    np.testing.assert_array_equal(col_nonzero, np.ones(n))


@given(n=st.integers(2, 24), s=st.integers(1, 5), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_resampling_matrix_properties(n, s, seed):
    m = np.asarray(Resampling(s).matrix(jax.random.PRNGKey(seed), n))
    assert m.shape == (n, n)
    # row-stochastic
    np.testing.assert_allclose(m.sum(axis=1), 1.0, rtol=1e-6)
    # each input replicated exactly s times total weight 1 (s copies x 1/s)
    np.testing.assert_allclose(m.sum(axis=0), 1.0, rtol=1e-6)
    # no input exceeds s appearances => max column weight <= s * (1/s) = 1,
    # per-entry weight is a multiple of 1/s
    ent = m[m > 0]
    np.testing.assert_allclose(np.round(ent * s), ent * s, atol=1e-6)


def test_nomix_is_identity(key):
    xs = jax.random.normal(key, (6, 9))
    np.testing.assert_array_equal(NoMix().apply(key, xs), xs)


def test_fixed_grouping_ignores_key(key):
    m1 = FixedGrouping(2).matrix(jax.random.PRNGKey(1), 10)
    m2 = FixedGrouping(2).matrix(jax.random.PRNGKey(2), 10)
    np.testing.assert_array_equal(m1, m2)


def test_get_mixer_registry():
    assert isinstance(get_mixer("bucketing", 3), Bucketing)
    assert isinstance(get_mixer("none"), NoMix)
    with pytest.raises(KeyError):
        get_mixer("nope")


# ----------------------------------------------------------------- Lemma 1
def test_lemma1_variance_reduction(key):
    """After s-mixing, pairwise variance drops by ~s (paper Lemma 1)."""
    n, d, s = 24, 64, 3
    xs = jax.random.normal(key, (n, d)) * 2.0
    rho2 = pairwise_variance(xs)
    # average over many resampling draws to estimate E||y_i - y_j||^2
    ratios = []
    for seed in range(20):
        ys = Bucketing(s).apply(jax.random.PRNGKey(seed), xs)
        ratios.append(float(pairwise_variance(ys) / rho2))
    mean_ratio = np.mean(ratios)
    # Lemma 1 bound: <= 1/s (with slack for the empirical estimate)
    assert mean_ratio < 1.0 / s * 1.5, mean_ratio


def test_lemma1_mean_preserved(key):
    """Mixing is mean-preserving: mean(ys) == mean(xs) exactly (row-stochastic
    with uniform column weights)."""
    xs = jax.random.normal(key, (12, 33))
    for mixer in (Bucketing(3), Resampling(2), FixedGrouping(4)):
        ys = mixer.apply(jax.random.PRNGKey(5), xs)
        # resampling keeps n rows with col sums 1 -> exact mean preservation;
        # bucketing weights buckets equally only when s | n, so compare the
        # column-weighted mean
        m = np.asarray(mixer.matrix(jax.random.PRNGKey(5), xs.shape[0]))
        w = m.sum(axis=0) / m.shape[0]
        expect = w @ np.asarray(xs)
        np.testing.assert_allclose(
            np.mean(np.asarray(ys), axis=0), expect, rtol=1e-5, atol=1e-5
        )


def test_byzantine_amplification_bounded(key):
    """At most f*s mixed outputs touch a Byzantine input (Lemma 1's tradeoff)."""
    n, f, s = 20, 3, 2
    for mixer in (Bucketing(s), Resampling(s)):
        m = np.asarray(mixer.matrix(key, n))
        touched = np.sum(np.any(m[:, :f] > 0, axis=1))
        assert touched <= f * s


# ------------------------------------------------------- stacked application
@given(s=st.integers(1, 4), seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_apply_matches_matrix(s, seed):
    key = jax.random.PRNGKey(seed)
    xs = jax.random.normal(jax.random.fold_in(key, 1), (13, 7))
    mixer = Bucketing(s)
    ys = mixer.apply(key, xs)
    # apply() must equal an explicit matmul with the same key
    m = mixer.matrix(jax.random.PRNGKey(seed), 13)
    np.testing.assert_allclose(ys, m @ xs, rtol=1e-5, atol=1e-6)
