"""Hypothesis property-based tests on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # absent in some environments
from hypothesis import given, settings, strategies as st

from repro.core.aggregators import get_aggregator
from repro.core.aragg import RobustAggregator

AGGS = ["mean", "cm", "rfa", "krum", "tm"]


def _xs(seed, n, d):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * 2.0


@given(name=st.sampled_from(AGGS), seed=st.integers(0, 100),
       n=st.integers(3, 15), d=st.integers(1, 30))
@settings(max_examples=40, deadline=None)
def test_aggregate_in_convex_hull_coordinatewise_bounds(name, seed, n, d):
    """Every aggregator's output is inside the coordinate-wise [min, max]
    envelope of its inputs (all rules are convex combinations / selections /
    order statistics)."""
    xs = _xs(seed, n, d)
    agg = get_aggregator(name)
    out = agg.aggregate(xs)
    lo, hi = jnp.min(xs, 0), jnp.max(xs, 0)
    assert bool(jnp.all(out >= lo - 1e-4)) and bool(jnp.all(out <= hi + 1e-4))


@given(name=st.sampled_from(AGGS), seed=st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_permutation_invariance(name, seed):
    """Aggregation must not depend on worker ordering (up to fp assoc)."""
    xs = _xs(seed, 9, 12)
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 1), 9)
    agg = get_aggregator(name)
    np.testing.assert_allclose(
        agg.aggregate(xs), agg.aggregate(xs[perm]), rtol=5e-4, atol=5e-4
    )


@given(name=st.sampled_from(["mean", "cm", "tm", "krum"]), seed=st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_translation_equivariance(name, seed):
    """agg(x + t) == agg(x) + t for selection/order-statistic rules."""
    xs = _xs(seed, 8, 10)
    t = jax.random.normal(jax.random.PRNGKey(seed + 7), (10,)) * 3
    agg = get_aggregator(name)
    np.testing.assert_allclose(
        agg.aggregate(xs + t), agg.aggregate(xs) + t, rtol=1e-3, atol=1e-3
    )


@given(seed=st.integers(0, 100), s=st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_robust_aggregator_scale_equivariance(seed, s):
    """ARAGG(c * x) == c * ARAGG(x) for positively homogeneous rules (mean,
    CM; RFA/Krum selections are scale-equivariant too)."""
    xs = _xs(seed, 10, 8)
    key = jax.random.PRNGKey(seed)
    for name in ("cm", "rfa"):
        ra = RobustAggregator.from_spec(name, mixing="bucketing", s=s)
        a = ra(3.0 * xs, key=key)
        b = 3.0 * ra(xs, key=key)
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


@given(seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_unanimity(seed):
    """If all workers agree, every rule returns that vector exactly."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (16,))
    xs = jnp.broadcast_to(x, (7, 16))
    for name in AGGS + ["cclip"]:
        agg = get_aggregator(name, **({"tau": 1.0} if name == "cclip" else {}))
        np.testing.assert_allclose(agg.aggregate(xs), x, rtol=1e-4, atol=1e-5)


@given(seed=st.integers(0, 500), W=st.integers(2, 20), d=st.integers(1, 50))
@settings(max_examples=30, deadline=None)
def test_kernel_gram_psd(seed, W, d):
    """The Pallas Gram kernel returns a symmetric PSD matrix."""
    from repro.kernels import pairwise_gram
    xs = jax.random.normal(jax.random.PRNGKey(seed), (W, d))
    g = np.asarray(pairwise_gram(xs))
    np.testing.assert_allclose(g, g.T, rtol=1e-5, atol=1e-5)
    eig = np.linalg.eigvalsh(g)
    assert eig.min() > -1e-3 * max(1.0, eig.max())


# ------------------------------------------- selection-network order engine
@given(seed=st.integers(0, 10_000), w=st.integers(2, 64), d=st.integers(1, 33))
@settings(max_examples=25, deadline=None)
def test_selection_median_matches_sort_oracle(seed, w, d):
    """Odd and even W, ragged d: the pruned-network median (Pallas kernel
    and pure-jnp apply) equals the jnp.sort oracle exactly — the network
    computes the same value multiset per column."""
    from repro.kernels import ops
    from repro.kernels.selection_network import median_select

    xs = _xs(seed, w, d)
    s = jnp.sort(xs, axis=0)
    want = s[w // 2] if w % 2 else 0.5 * (s[w // 2 - 1] + s[w // 2])
    np.testing.assert_array_equal(np.asarray(median_select(xs)), np.asarray(want))
    np.testing.assert_allclose(ops.cm_aggregate(xs), want, rtol=1e-6, atol=1e-6)


@given(seed=st.integers(0, 10_000), w=st.integers(2, 64), d=st.integers(1, 33),
       data=st.data())
@settings(max_examples=25, deadline=None)
def test_selection_trimmed_mean_matches_sort_oracle(seed, w, d, data):
    from repro.kernels import ops
    from repro.kernels.selection_network import trimmed_mean_select

    b = data.draw(st.integers(0, (w - 1) // 2))
    xs = _xs(seed, w, d)
    want = jnp.mean(jnp.sort(xs, axis=0)[b: w - b], axis=0)
    np.testing.assert_allclose(trimmed_mean_select(xs, b), want,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ops.tm_aggregate(xs, b), want,
                               rtol=1e-5, atol=1e-5)


@given(seed=st.integers(0, 10_000), w=st.integers(2, 32), pad=st.integers(1, 8))
@settings(max_examples=25, deadline=None)
def test_selection_inf_padding_rows_are_inert(seed, w, pad):
    """Sentinel elimination: +inf padding rows below the real rows never
    alter the real order statistics (the property that lets the kernels
    filter the Batcher network to pairs with j < W)."""
    from repro.kernels.selection_network import select_rows

    xs = _xs(seed, w, 7)
    padded = jnp.concatenate([xs, jnp.full((pad, 7), jnp.inf)], axis=0)
    s = jnp.sort(xs, axis=0)
    got = select_rows(padded, range(w))
    for r in range(w):
        np.testing.assert_array_equal(np.asarray(got[r]), np.asarray(s[r]))


@given(seed=st.integers(0, 10_000), w=st.integers(2, 64), data=st.data())
@settings(max_examples=25, deadline=None)
def test_selection_non_contiguous_rank_subsets(seed, w, data):
    """Arbitrary (non-contiguous) rank sets match the sort oracle
    rank-for-rank, and rank pruning never produces a program larger than
    the full filtered network."""
    from repro.kernels.selection_network import select_rows, selection_program

    ranks = tuple(sorted(data.draw(
        st.sets(st.integers(0, w - 1), min_size=1, max_size=min(w, 6)))))
    xs = _xs(seed, w, 9)
    s = jnp.sort(xs, axis=0)
    for r, row in zip(ranks, select_rows(xs, ranks)):
        np.testing.assert_array_equal(np.asarray(row), np.asarray(s[r]))
    assert len(selection_program(w, ranks)) <= len(
        selection_program(w, tuple(range(w))))
