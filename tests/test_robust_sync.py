"""Factorized (Gram-space, never-stacked) robust sync == stacked semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aragg import RobustAggregator
from repro.distributed.robust_sync import (
    robust_gradient_sync,
    tree_combine,
    tree_gram,
    tree_mix,
)


def _worker_tree(key, W=8):
    ks = jax.random.split(key, 3)
    return {
        "a": jax.random.normal(ks[0], (W, 4, 6)),
        "b": {"w": jax.random.normal(ks[1], (W, 10)),
              "v": jax.random.normal(ks[2], (W, 3, 2, 2))},
    }


def _stack(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    W = leaves[0].shape[0]
    return jnp.concatenate([x.reshape(W, -1) for x in leaves], axis=1)


def test_tree_gram_matches_stacked(key):
    tree = _worker_tree(key)
    flat = _stack(tree)
    np.testing.assert_allclose(tree_gram(tree, 8), flat @ flat.T, rtol=1e-5,
                               atol=1e-4)


def test_tree_combine_matches_matmul(key):
    tree = _worker_tree(key)
    w = jax.random.normal(jax.random.fold_in(key, 1), (8,))
    out = tree_combine(tree, w)
    flat_out = jnp.concatenate(
        [x.reshape(-1) for x in jax.tree_util.tree_leaves(out)]
    )
    np.testing.assert_allclose(flat_out, w @ _stack(tree), rtol=1e-5, atol=1e-5)


def test_tree_mix_shapes(key):
    tree = _worker_tree(key)
    m = jnp.full((4, 8), 1 / 8)
    mixed = tree_mix(tree, m)
    assert jax.tree_util.tree_leaves(mixed)[0].shape[0] == 4


@pytest.mark.parametrize("agg,mixing", [
    ("mean", "none"),
    ("krum", "bucketing"),
    ("rfa", "bucketing"),
    ("rfa", "resampling"),
    ("cclip", "bucketing"),
    ("cm", "bucketing"),
    ("tm", "none"),
])
def test_factorized_equals_stacked(key, agg, mixing):
    """The distributed path's output == RobustAggregator on the stacked
    vector, for every aggregator family and mixer (DESIGN.md §4)."""
    W = 12
    tree = _worker_tree(key, W)
    kwargs = {"n_byzantine": 2} if agg == "krum" else (
        {"tau": 3.0} if agg == "cclip" else ({"n_trim": 2} if agg == "tm" else {}))
    ra = RobustAggregator.from_spec(agg, mixing=mixing, s=3, **kwargs)

    agg_key = jax.random.PRNGKey(42)
    out_tree, info = robust_gradient_sync(tree, ra, key=agg_key)
    flat_out = jnp.concatenate(
        [x.reshape(-1) for x in jax.tree_util.tree_leaves(out_tree)]
    )
    stacked_out = ra(_stack(tree), key=agg_key)
    np.testing.assert_allclose(flat_out, stacked_out, rtol=2e-4, atol=2e-4)


def test_sync_reduces_byzantine_influence(key):
    """End to end: with 2/12 Byzantine leaves blown up, robust sync output
    stays near the good mean while plain mean is destroyed."""
    W = 12
    tree = _worker_tree(key, W)
    # blow up the first two workers' updates
    tree = jax.tree_util.tree_map(
        lambda x: x.at[:2].set(1e4), tree
    )
    good_mean = jnp.concatenate([
        x[2:].mean(0).reshape(-1) for x in jax.tree_util.tree_leaves(tree)
    ])
    ra = RobustAggregator.from_spec("rfa", mixing="bucketing", s=2)
    out, _ = robust_gradient_sync(tree, ra, key=key)
    flat = jnp.concatenate([x.reshape(-1) for x in jax.tree_util.tree_leaves(out)])
    err_robust = float(jnp.linalg.norm(flat - good_mean))

    mean_ra = RobustAggregator.from_spec("mean", mixing="none")
    out_m, _ = robust_gradient_sync(tree, mean_ra, key=key)
    flat_m = jnp.concatenate([x.reshape(-1) for x in jax.tree_util.tree_leaves(out_m)])
    err_mean = float(jnp.linalg.norm(flat_m - good_mean))
    # GM with 8 Weiszfeld iters keeps a small residual at 1e4-magnitude
    # outliers; the robustness claim is the ~100x error reduction vs mean.
    assert err_mean > 1e3
    assert err_robust < 0.05 * err_mean, (err_robust, err_mean)
