"""Packed flat-buffer robust-aggregation engine (distributed/packing.py):
layout round-trips, BIT-exact agreement with the per-leaf oracle, the
one-collective-per-phase schedule, and the flat-stack entry point."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aragg import RobustAggregator
from repro.distributed import packing
from repro.distributed.packing import packed_aggregate, packer_for
from repro.distributed.robust_sync import robust_gradient_sync

BLOCK_D = 256  # small blocks so tiny test leaves still span multiple blocks


def _mixed_dtype_tree(key, W=6):
    ks = jax.random.split(key, 4)
    return {
        "w": jax.random.normal(ks[0], (W, 4, 6), jnp.float32),
        "b": jax.random.normal(ks[1], (W,), jnp.float32).astype(jnp.bfloat16),
        "e": jnp.zeros((W, 0, 3), jnp.float32),  # empty leaf
        "h": jax.random.normal(ks[2], (W, 513), jnp.float32).astype(jnp.float16),
        "s": {"v": jax.random.normal(ks[3], (W, 3, 2, 2), jnp.float32)},
    }


def _f32_tree(key, W=12, sizes=((24,), (300,), (7, 11), (1000,), (2, 0))):
    ks = jax.random.split(key, len(sizes))
    return {f"l{i}": jax.random.normal(k, (W,) + s, jnp.float32)
            for i, (k, s) in enumerate(zip(ks, sizes))}


# ------------------------------------------------------------------- layout
def test_pack_unpack_roundtrip_mixed_dtypes(key):
    tree = _mixed_dtype_tree(key)
    packer = packer_for(tree, block_d=BLOCK_D)
    buf = packer.pack(tree)
    assert buf.dtype == jnp.float32
    assert buf.shape == (6, packer.n_pad)
    assert packer.n_pad % BLOCK_D == 0
    # every leaf segment starts on a block boundary (bit-exactness alignment)
    assert all(off % BLOCK_D == 0 for off in packer.offsets)
    back = packer.unpack_stacked(buf)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # single-row unpack slices worker 0 exactly
    row = packer.unpack(buf[0])
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(row)):
        np.testing.assert_array_equal(np.asarray(a[0], np.float32),
                                      np.asarray(b, np.float32))


def test_packer_layout_is_cached(key):
    tree = _f32_tree(key)
    assert packer_for(tree, block_d=BLOCK_D) is packer_for(tree, block_d=BLOCK_D)
    assert packer_for(tree, block_d=BLOCK_D) is not packer_for(tree, block_d=512)


def test_packer_cache_distinct_for_dtype_and_block(key):
    """Trees that differ ONLY in a leaf dtype (or in block_d) must map to
    distinct cached layouts — dtype drives the unpack cast."""
    tree32 = {"a": jnp.zeros((4, 37), jnp.float32),
              "b": jnp.zeros((4, 5, 3), jnp.float32)}
    tree16 = {"a": tree32["a"], "b": tree32["b"].astype(jnp.bfloat16)}
    p32 = packer_for(tree32, block_d=BLOCK_D)
    p16 = packer_for(tree16, block_d=BLOCK_D)
    assert p32 is not p16
    assert p16.leaf_dtypes[1] == jnp.bfloat16
    assert packer_for(tree32, block_d=2 * BLOCK_D) is not p32
    # same shapes+dtypes+block -> the SAME object
    assert packer_for({k: v + 1 for k, v in tree32.items()},
                      block_d=BLOCK_D) is p32


def test_packer_built_once_across_syncs_in_one_trace(key, monkeypatch):
    """Two packed_robust_sync calls on the same tree structure inside ONE
    jit trace must hit the layout cache — GradPacker is built at most once
    (zero times if a previous test already cached this layout; use a unique
    shape so the first call builds)."""
    builds = {"n": 0}
    orig_init = packing.GradPacker.__init__

    def counting_init(self, *a, **kw):
        builds["n"] += 1
        orig_init(self, *a, **kw)

    monkeypatch.setattr(packing.GradPacker, "__init__", counting_init)
    tree = _f32_tree(key, W=5, sizes=((131,), (9, 3)))  # unique layout
    ra = RobustAggregator.from_spec("cm", mixing="bucketing", s=2)

    @jax.jit
    def two_syncs(t, k):
        o1, _ = packing.packed_robust_sync(t, ra, key=k, block_d=BLOCK_D)
        o2, _ = packing.packed_robust_sync(t, ra, key=k, block_d=BLOCK_D)
        return o1, o2

    two_syncs(tree, jax.random.PRNGKey(0))
    assert builds["n"] == 1


@pytest.mark.parametrize("engine", ["packed", "per_leaf"])
@pytest.mark.parametrize("use_kernels", [True, False])
def test_empty_leaf_through_both_engines(key, engine, use_kernels):
    """A zero-size leaf inside an otherwise normal tree must pass through
    both engines (guarded before any reshape/reshard) and come back as a
    zero array of the right trailing shape."""
    tree = {"a": jax.random.normal(key, (6, 40), jnp.float32),
            "empty": jnp.zeros((6, 2, 0), jnp.float32),
            "b": jax.random.normal(key, (6, 3, 5), jnp.float32)}
    for agg in ("rfa", "cm"):
        ra = RobustAggregator.from_spec(agg, mixing="bucketing", s=2)
        out, _ = robust_gradient_sync(tree, ra, key=jax.random.PRNGKey(1),
                                      engine=engine, block_d=BLOCK_D,
                                      use_kernels=use_kernels)
        assert out["empty"].shape == (2, 0)
        assert out["a"].shape == (40,) and out["b"].shape == (3, 5)
        assert np.all(np.isfinite(np.asarray(out["a"])))


def test_empty_tree_degenerate():
    tree = {"e": jnp.zeros((4, 0), jnp.float32)}
    ra = RobustAggregator.from_spec("rfa", mixing="none")
    out, _ = robust_gradient_sync(tree, ra, engine="packed", block_d=BLOCK_D)
    assert out["e"].shape == (0,)


# ----------------------------------------------- bit-exactness vs the oracle
RULES = [
    ("krum", {"n_byzantine": 2}),
    ("rfa", {}),
    ("cclip", {"tau": 3.0}),
    ("cm", {}),
    ("tm", {"n_trim": 2}),
    ("mean", {}),
]
MIXINGS = ["none", "bucketing", "resampling"]


@pytest.mark.parametrize("agg,kwargs", RULES, ids=[r[0] for r in RULES])
@pytest.mark.parametrize("mixing", MIXINGS)
def test_packed_bit_identical_to_per_leaf_oracle(key, agg, kwargs, mixing):
    """The packed engine performs the identical fp32 operation sequence as
    the per-leaf kernel oracle (leaf segments are block-aligned, the Gram
    kernel chains its accumulator), so outputs match BIT FOR BIT."""
    tree = _f32_tree(key)
    ra = RobustAggregator.from_spec(agg, mixing=mixing, s=3, **kwargs)
    agg_key = jax.random.PRNGKey(42)
    out_p, info_p = robust_gradient_sync(tree, ra, key=agg_key,
                                         engine="packed", block_d=BLOCK_D)
    out_o, info_o = robust_gradient_sync(tree, ra, key=agg_key,
                                         engine="per_leaf", block_d=BLOCK_D,
                                         use_kernels=True)
    for lp, lo in zip(jax.tree_util.tree_leaves(out_p),
                      jax.tree_util.tree_leaves(out_o)):
        np.testing.assert_array_equal(np.asarray(lp), np.asarray(lo))
    if "agg_weights" in info_p:
        np.testing.assert_array_equal(np.asarray(info_p["agg_weights"]),
                                      np.asarray(info_o["agg_weights"]))


@pytest.mark.parametrize("agg,mixing", [
    ("krum", "bucketing"), ("rfa", "resampling"), ("cclip", "bucketing"),
    ("cm", "bucketing"),
])
def test_packed_matches_stacked_semantics(key, agg, mixing):
    """Against the original stacked RobustAggregator (value semantics)."""
    tree = _f32_tree(key)
    kwargs = {"n_byzantine": 2} if agg == "krum" else (
        {"tau": 3.0} if agg == "cclip" else {})
    ra = RobustAggregator.from_spec(agg, mixing=mixing, s=3, **kwargs)
    agg_key = jax.random.PRNGKey(7)
    out, _ = robust_gradient_sync(tree, ra, key=agg_key, engine="packed",
                                  block_d=BLOCK_D)
    flat_out = jnp.concatenate(
        [x.reshape(-1) for x in jax.tree_util.tree_leaves(out)]
    )
    leaves = jax.tree_util.tree_leaves(tree)
    stacked = jnp.concatenate([x.reshape(x.shape[0], -1) for x in leaves], axis=1)
    expect = ra(stacked, key=agg_key)
    np.testing.assert_allclose(flat_out, expect, rtol=2e-4, atol=2e-4)


# ------------------------------------------------------- collective schedule
@pytest.mark.parametrize("n_leaves", [3, 17])
@pytest.mark.parametrize("agg", ["rfa", "cm"])
def test_exactly_one_reshard_pair_per_sync(key, monkeypatch, agg, n_leaves):
    """One reshard-in and one reshard-out per sync, REGARDLESS of leaf count
    (the per-leaf path pays two collectives per leaf — the point of the
    packed engine)."""
    sizes = tuple((16 + i,) for i in range(n_leaves))
    tree = _f32_tree(key, W=8, sizes=sizes)
    calls = {"in": 0, "out": 0}
    orig_in, orig_out = packing.reshard_in, packing.reshard_out

    def count_in(buf, mesh):
        calls["in"] += 1
        return orig_in(buf, mesh)

    def count_out(vec, mesh):
        calls["out"] += 1
        return orig_out(vec, mesh)

    monkeypatch.setattr(packing, "reshard_in", count_in)
    monkeypatch.setattr(packing, "reshard_out", count_out)
    ra = RobustAggregator.from_spec(agg, mixing="bucketing", s=2)
    robust_gradient_sync(tree, ra, key=key, engine="packed", block_d=BLOCK_D)
    assert calls == {"in": 1, "out": 1}


# ------------------------------------------------------- telemetry contract
@pytest.mark.parametrize("agg,kwargs", [("rfa", {}), ("cm", {}),
                                        ("cclip", {"tau": 3.0})],
                         ids=["rfa", "cm", "cclip"])
def test_telemetry_off_is_bit_exact_on_is_close(key, agg, kwargs):
    """``telemetry=False`` (explicit) must execute the SEED program — output
    bit-identical to the default call AND to the per-leaf kernel oracle
    (the existing bit-exactness bar is untouched by the observability
    layer). ``telemetry=True`` may differ only at XLA-fusion level (~1 ulp)
    and must carry the metrics pytree in the info dict."""
    tree = _f32_tree(key)
    ra = RobustAggregator.from_spec(agg, mixing="bucketing", s=3, **kwargs)
    k = jax.random.PRNGKey(17)
    out_def, info_def = robust_gradient_sync(tree, ra, key=k, engine="packed",
                                             block_d=BLOCK_D)
    out_off, info_off = robust_gradient_sync(tree, ra, key=k, engine="packed",
                                             block_d=BLOCK_D, telemetry=False)
    out_oracle, _ = robust_gradient_sync(tree, ra, key=k, engine="per_leaf",
                                         block_d=BLOCK_D, use_kernels=True)
    assert "telemetry" not in info_def and "telemetry" not in info_off
    for a, b, c in zip(jax.tree_util.tree_leaves(out_off),
                       jax.tree_util.tree_leaves(out_def),
                       jax.tree_util.tree_leaves(out_oracle)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))

    out_on, info_on = robust_gradient_sync(tree, ra, key=k, engine="packed",
                                           block_d=BLOCK_D, telemetry=True)
    assert "telemetry" in info_on and info_on["telemetry"]
    for a, b in zip(jax.tree_util.tree_leaves(out_on),
                    jax.tree_util.tree_leaves(out_off)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-6, atol=2e-6)


# ---------------------------------------------------------- flat-stack entry
def test_packed_aggregate_flat_stack(key):
    xs = jax.random.normal(key, (10, 700), jnp.float32)
    for agg, kwargs in [("rfa", {}), ("cm", {}), ("cclip", {"tau": 5.0})]:
        ra = RobustAggregator.from_spec(agg, mixing="bucketing", s=2, **kwargs)
        k = jax.random.PRNGKey(3)
        out = packed_aggregate(xs, ra, key=k, block_d=BLOCK_D)
        np.testing.assert_allclose(out, ra(xs, key=k), rtol=2e-4, atol=2e-4)
        assert out.shape == (700,)
