"""(delta_max, c)-ARAGG composition (Definition A / Theorem I)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aragg import DELTA_MAX, RobustAggregator, theorem1_s
from repro.core.theory import pairwise_variance


def test_theorem1_s_values():
    assert theorem1_s(0.0, 0.5, 20) == 1
    assert theorem1_s(0.1, 0.5, 20) == 5
    assert theorem1_s(0.1, 0.25, 20) == 2
    assert theorem1_s(0.3, 0.25, 20) == 1  # never below 1
    assert theorem1_s(0.01, 0.5, 10) == 10  # capped at n


def test_from_spec_derives_s():
    ra = RobustAggregator.from_spec("rfa", mixing="bucketing", s=None, delta=0.1,
                                    n_workers=20)
    assert ra.mixer.s == theorem1_s(0.1, DELTA_MAX["rfa"], 20) == 5


def test_from_spec_explicit_s():
    ra = RobustAggregator.from_spec("cm", mixing="resampling", s=3)
    assert ra.mixer.s == 3


@pytest.mark.parametrize("agg", ["krum", "cm", "rfa"])
def test_definition_a_error_bound(key, agg):
    """E||ARAGG(x) - xbar||^2 <= c * delta * rho^2 for a moderate c —
    the Definition-A contract, checked empirically on a Byzantine instance."""
    n, f, d = 20, 2, 48
    delta = f / n
    k1, k2 = jax.random.split(key)
    good = jax.random.normal(k1, (n - f, d))
    xbar = jnp.mean(good, axis=0)
    byz = jnp.full((f, d), 30.0)  # far outliers
    xs = jnp.concatenate([byz, good], axis=0)
    rho2 = float(pairwise_variance(good))

    kwargs = {"n_byzantine": f} if agg == "krum" else {}
    ra = RobustAggregator.from_spec(agg, mixing="bucketing", s=None, delta=delta,
                                    n_workers=n, **kwargs)
    errs = []
    for seed in range(16):
        out = ra(xs, key=jax.random.PRNGKey(seed))
        errs.append(float(jnp.sum(jnp.square(out - xbar))))
    mean_err = np.mean(errs)
    # c = 50 is a loose empirical constant; the point is the delta*rho^2 scale
    # vs the unmixed failure mode which is O(byz_val^2) ~ 900 * d
    assert mean_err <= 50 * delta * rho2, (mean_err, delta * rho2)


def test_exact_recovery_when_no_byzantine_and_zero_variance(key):
    """delta=0, rho=0 => exact recovery of the average (Definition A)."""
    x = jax.random.normal(key, (16,))
    xs = jnp.broadcast_to(x, (10, 16))
    for agg in ("krum", "cm", "rfa"):
        ra = RobustAggregator.from_spec(agg, mixing="bucketing", s=2)
        np.testing.assert_allclose(ra(xs, key=key), x, rtol=1e-5, atol=1e-6)


def test_mixing_reduces_aggregation_error_noniid(key):
    """The paper's §3.1 failure: on heterogeneous inputs with NO Byzantine
    workers, Krum-without-mixing has a large error; with bucketing the error
    shrinks substantially (Tables 1 vs 3)."""
    n, d = 20, 32
    # heterogeneous: each worker's vector points at a different "class"
    xs = 5.0 * jax.nn.one_hot(jnp.arange(n) % 10, d) + \
        0.1 * jax.random.normal(key, (n, d))
    xbar = jnp.mean(xs, axis=0)

    vanilla = RobustAggregator.from_spec("krum", mixing="none", n_byzantine=0)
    mixed = RobustAggregator.from_spec("krum", mixing="bucketing", s=5,
                                       n_byzantine=0)
    err_vanilla = float(jnp.linalg.norm(vanilla(xs, key=key) - xbar))
    errs_mixed = [
        float(jnp.linalg.norm(mixed(xs, key=jax.random.PRNGKey(i)) - xbar))
        for i in range(8)
    ]
    assert np.mean(errs_mixed) < 0.7 * err_vanilla, (np.mean(errs_mixed), err_vanilla)


def test_worker_weights_from_gram_matches_call(key):
    xs = jax.random.normal(key, (12, 40))
    ra = RobustAggregator.from_spec("rfa", mixing="bucketing", s=2)
    out_direct = ra(xs, key=key)
    gram = xs @ xs.T
    w = ra.worker_weights_from_gram(gram, key=key)
    np.testing.assert_allclose(out_direct, w @ xs, rtol=1e-4, atol=1e-5)
