"""Hand-rolled optimizers vs closed-form expectations."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import make_optimizer
from repro.optim.optimizers import adamw_init, adamw_update, sgdm_init, sgdm_update


def test_sgd_plain_step():
    params = {"w": jnp.asarray([1.0, 2.0])}
    grads = {"w": jnp.asarray([0.5, -0.5])}
    init, update = make_optimizer("sgd", lr=0.1)
    state = init(params)
    new, state = update(grads, state, params)
    np.testing.assert_allclose(new["w"], [0.95, 2.05], rtol=1e-6)


def test_sgdm_momentum_accumulates():
    params = {"w": jnp.zeros(2)}
    grads = {"w": jnp.ones(2)}
    state = sgdm_init(params)
    p = params
    # m_t = sum_{k<=t} beta^{t-k} g  (pytorch convention) => after 2 steps
    p, state = sgdm_update(grads, state, p, lr=1.0, beta=0.5)
    np.testing.assert_allclose(p["w"], -1.0)  # m1 = 1
    p, state = sgdm_update(grads, state, p, lr=1.0, beta=0.5)
    np.testing.assert_allclose(p["w"], -2.5)  # m2 = 1.5


def test_adamw_first_step_is_lr_signed():
    """With bias correction, |step 1| == lr * g/|g| (up to eps)."""
    params = {"w": jnp.asarray([0.0, 0.0])}
    grads = {"w": jnp.asarray([0.3, -0.7])}
    state = adamw_init(params)
    new, _ = adamw_update(grads, state, params, lr=0.01)
    np.testing.assert_allclose(jnp.abs(new["w"]), 0.01, rtol=1e-4)
    assert float(new["w"][0]) < 0 and float(new["w"][1]) > 0


def test_adamw_weight_decay_shrinks():
    params = {"w": jnp.asarray([10.0])}
    grads = {"w": jnp.asarray([0.0])}
    state = adamw_init(params)
    new, _ = adamw_update(grads, state, params, lr=0.1, weight_decay=0.1)
    assert float(new["w"][0]) < 10.0


def test_optimizer_converges_quadratic():
    """Both optimizers minimize a quadratic."""
    target = jnp.asarray([3.0, -2.0])

    def gradf(p):
        return {"w": p["w"] - target}

    for name in ("sgdm", "adamw"):
        init, update = make_optimizer(name, lr=0.1)
        p = {"w": jnp.zeros(2)}
        s = init(p)
        for _ in range(200):
            p, s = update(gradf(p), s, p)
        np.testing.assert_allclose(p["w"], target, atol=0.05)


def test_sgdm_bf16_momentum_storage():
    """opt_m_dtype=bfloat16 halves optimizer HBM (kimi-k2 fit lever) while
    accumulating the update in fp32."""
    import jax.numpy as jnp
    from repro.optim import make_optimizer

    init, update = make_optimizer("sgdm", lr=0.1, m_dtype="bfloat16")
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    state = init(params)
    assert state.m["w"].dtype == jnp.bfloat16
    p, state = update({"w": jnp.ones(4)}, state, params)
    assert state.m["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(p["w"].astype(jnp.float32))))


def test_lr_schedules():
    import numpy as np
    from repro.optim.schedule import constant_lr, cosine_lr, warmup_cosine_lr

    np.testing.assert_allclose(float(constant_lr(0.1)(1000)), 0.1, rtol=1e-6)
    cos = cosine_lr(1.0, 100, min_frac=0.1)
    np.testing.assert_allclose(float(cos(0)), 1.0, rtol=1e-6)
    np.testing.assert_allclose(float(cos(100)), 0.1, rtol=1e-5)
    assert float(cos(50)) < float(cos(10))
    wc = warmup_cosine_lr(1.0, 200, warmup_steps=50)
    assert float(wc(0)) == 0.0
    np.testing.assert_allclose(float(wc(50)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(wc(25)), 0.5, rtol=1e-6)
