"""End-to-end system behaviour: a short LLM Byzantine training run with the
full distributed step factory (1-device mesh) must decrease training loss
with the robust path active."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.configs.base import ByzConfig, InputShape
from repro.distributed.steps import make_train_step
from repro.launch.mesh import make_host_mesh, n_workers
from repro.models import transformer as tfm
from repro.optim import make_optimizer


@pytest.mark.slow
def test_llm_train_loss_decreases():
    cfg = smoke_config("tinyllama-1.1b")
    mesh = make_host_mesh(1, 1)
    byz = ByzConfig(aggregator="rfa", mixing="bucketing", s=2,
                    worker_momentum=0.9)
    shape = InputShape("tiny", seq_len=64, global_batch=8, kind="train")
    with mesh:
        step_fn, sh = make_train_step(cfg, byz, mesh, lr=0.3)
        step_fn = jax.jit(step_fn)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        opt_init, _ = make_optimizer("sgdm")
        opt_state = opt_init(params)
        worker_m = jax.tree_util.tree_map(
            lambda x: jnp.zeros((n_workers(mesh),) + x.shape, jnp.float32),
            params) if sh["worker_m"] else {}

        # deterministic affine-bigram stream => learnable next-token law
        key = jax.random.PRNGKey(1)
        losses = []
        for t in range(30):
            k = jax.random.fold_in(key, t)
            start = jax.random.randint(k, (shape.global_batch, 1), 0,
                                       cfg.vocab_size)
            seq = [start]
            for _ in range(shape.seq_len):
                seq.append((seq[-1] * 3 + 7) % cfg.vocab_size)
            toks = jnp.concatenate(seq, axis=1)
            batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
            params, opt_state, worker_m, metrics = step_fn(
                params, opt_state, worker_m, k, batch)
            losses.append(float(metrics["loss"]))
    assert all(jnp.isfinite(jnp.asarray(losses)))
    assert losses[-1] < losses[0] * 0.8, losses[::10]
