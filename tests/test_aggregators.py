"""Unit tests for the robust aggregation rules (stacked + Gram-space forms)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregators import (
    CenteredClip,
    CoordinateWiseMedian,
    Krum,
    Mean,
    RFA,
    TrimmedMean,
    get_aggregator,
)


def _good_cluster(key, n_good, n_byz, d=32, spread=0.1, byz_val=100.0):
    """n_good points near a known mean + n_byz far outliers (byz rows first)."""
    mu = jnp.linspace(-1.0, 1.0, d)
    good = mu + spread * jax.random.normal(key, (n_good, d))
    byz = jnp.full((n_byz, d), byz_val)
    return jnp.concatenate([byz, good], axis=0), jnp.mean(good, axis=0)


# ------------------------------------------------------------------- mean
def test_mean_is_average(key):
    xs = jax.random.normal(key, (7, 11))
    np.testing.assert_allclose(Mean().aggregate(xs), jnp.mean(xs, 0), rtol=1e-6)


# ------------------------------------------------------------------- krum
def test_krum_rejects_outlier(key):
    xs, good_mean = _good_cluster(key, n_good=9, n_byz=2)
    out = Krum(n_byzantine=2).aggregate(xs)
    assert jnp.linalg.norm(out - good_mean) < 1.0
    # and it selected one of the good rows exactly
    assert any(jnp.allclose(out, xs[i]) for i in range(2, 11))


def test_krum_selected_index_is_good(key):
    xs, _ = _good_cluster(key, n_good=9, n_byz=2)
    idx = int(Krum(n_byzantine=2).selected_index(xs))
    assert idx >= 2  # byzantine rows are [0, 2)


def test_multi_krum_averages_m_rows(key):
    xs, good_mean = _good_cluster(key, n_good=9, n_byz=2)
    out = Krum(n_byzantine=2, m=3).aggregate(xs)
    assert jnp.linalg.norm(out - good_mean) < 1.0


# --------------------------------------------------------------------- cm
def test_cm_is_coordinatewise_median(key):
    xs = jax.random.normal(key, (9, 17))
    np.testing.assert_allclose(
        CoordinateWiseMedian().aggregate(xs), jnp.median(xs, axis=0), rtol=1e-6
    )


def test_cm_robust_to_large_outliers(key):
    xs, good_mean = _good_cluster(key, n_good=9, n_byz=2, byz_val=1e6)
    out = CoordinateWiseMedian().aggregate(xs)
    assert jnp.linalg.norm(out - good_mean) < 1.0


# --------------------------------------------------------------------- tm
def test_trimmed_mean_drops_extremes(key):
    xs, good_mean = _good_cluster(key, n_good=9, n_byz=2, byz_val=1e6)
    out = TrimmedMean(n_trim=2).aggregate(xs)
    assert jnp.linalg.norm(out - good_mean) < 1.0


def test_trimmed_mean_zero_trim_is_mean(key):
    xs = jax.random.normal(key, (6, 5))
    np.testing.assert_allclose(
        TrimmedMean(n_trim=0).aggregate(xs), jnp.mean(xs, 0), rtol=1e-5
    )


# -------------------------------------------------------------------- rfa
def test_rfa_close_to_geometric_median(key):
    xs, good_mean = _good_cluster(key, n_good=19, n_byz=4, byz_val=50.0)
    out = RFA(n_iters=16).aggregate(xs)
    # geometric median of 19 tight + 4 far points stays near the cluster
    assert jnp.linalg.norm(out - good_mean) < 1.0


def test_rfa_exact_on_identical_inputs(key):
    x = jax.random.normal(key, (8,))
    xs = jnp.broadcast_to(x, (5, 8))
    np.testing.assert_allclose(RFA().aggregate(xs), x, rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------------ cclip
def test_cclip_limits_outlier_influence(key):
    """CCLIP starts from the mean (its v0) and iterates clipped corrections;
    with a sane radius it converges near the good mean while plain averaging
    stays biased by delta * byz_val."""
    xs, good_mean = _good_cluster(key, n_good=9, n_byz=2, byz_val=10.0)
    out_clip = CenteredClip(tau=1.0, n_iters=30).aggregate(xs)
    out_mean = jnp.mean(xs, axis=0)
    # clipping pulls the aggregate far closer to the good mean than averaging
    assert jnp.linalg.norm(out_clip - good_mean) < 0.3 * jnp.linalg.norm(
        out_mean - good_mean
    )


def test_cclip_large_tau_equals_mean(key):
    xs = jax.random.normal(key, (6, 12))
    np.testing.assert_allclose(
        CenteredClip(tau=1e9, n_iters=3).aggregate(xs), jnp.mean(xs, 0),
        rtol=1e-4, atol=1e-5,
    )


# ------------------------------------------ stacked == Gram-space equivalence
@pytest.mark.parametrize("name,kwargs", [
    ("mean", {}),
    ("krum", {"n_byzantine": 2}),
    ("rfa", {}),
    ("cclip", {"tau": 2.0}),
])
def test_gram_space_matches_stacked(key, name, kwargs):
    xs = jax.random.normal(key, (11, 23)) * 2.0
    agg = get_aggregator(name, **kwargs)
    stacked = agg.aggregate(xs)
    gram = xs @ xs.T
    w = agg.coeffs(gram)
    via_gram = w @ xs
    np.testing.assert_allclose(stacked, via_gram, rtol=2e-4, atol=2e-5)


def test_registry_unknown_raises():
    with pytest.raises(KeyError):
        get_aggregator("nope")


# ----------------------------------------------------- acclip (beyond-paper)
def test_acclip_scale_invariant(key):
    """The adaptive radius makes ACClip exactly scale-equivariant — the
    agnosticity property fixed-tau CCLIP lacks (paper §6.4 open problem)."""
    from repro.core.aggregators.cclip import AdaptiveCenteredClip

    xs, _ = _good_cluster(key, n_good=9, n_byz=2, byz_val=30.0)
    agg = AdaptiveCenteredClip(n_iters=5)
    out = agg.aggregate(xs)
    out_scaled = agg.aggregate(1000.0 * xs)
    np.testing.assert_allclose(out_scaled, 1000.0 * out, rtol=1e-4)

    # fixed-tau CCLIP is NOT scale equivariant (radius stops binding)
    fixed = CenteredClip(tau=1.0, n_iters=5)
    bad = fixed.aggregate(1000.0 * xs)
    assert not np.allclose(bad, 1000.0 * fixed.aggregate(xs), rtol=1e-2)


def test_acclip_robust_across_scales(key):
    """ACClip stays near the good mean for outliers at any magnitude,
    with NO tuning."""
    from repro.core.aggregators.cclip import AdaptiveCenteredClip

    agg = AdaptiveCenteredClip(n_iters=10)
    for byz_val in (10.0, 1e3, 1e6):
        xs, good_mean = _good_cluster(key, n_good=9, n_byz=2, byz_val=byz_val)
        out = agg.aggregate(xs)
        err = float(jnp.linalg.norm(out - good_mean))
        err_mean = float(jnp.linalg.norm(jnp.mean(xs, 0) - good_mean))
        assert err < 0.05 * err_mean, (byz_val, err, err_mean)


def test_acclip_unanimity(key):
    from repro.core.aggregators.cclip import AdaptiveCenteredClip

    x = jax.random.normal(key, (16,))
    xs = jnp.broadcast_to(x, (7, 16))
    np.testing.assert_allclose(
        AdaptiveCenteredClip().aggregate(xs), x, rtol=1e-5, atol=1e-6)


def test_acclip_gram_matches_stacked(key):
    from repro.core.aggregators.cclip import AdaptiveCenteredClip

    xs = jax.random.normal(key, (11, 23)) * 2.0
    agg = AdaptiveCenteredClip(n_iters=4)
    gram = xs @ xs.T
    np.testing.assert_allclose(
        agg.aggregate(xs), agg.coeffs(gram) @ xs, rtol=2e-4, atol=2e-5)
