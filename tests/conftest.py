"""Shared fixtures. Deliberately does NOT set xla_force_host_platform_device_count:
smoke tests and benchmarks must see the real single CPU device (the 512
placeholder devices exist only inside repro.launch.dryrun)."""

import jax
import pytest


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration tests")
