"""Figure 6 (App. A.2.2): which workers does Krum select?

Without mixing on non-iid data under label flipping, Krum overwhelmingly
selects Byzantine workers (their full-dataset gradients look 'central');
with bucketing the selection spreads evenly over good workers. We measure
the fraction of rounds in which the selected (possibly mixed) update has any
Byzantine contribution, and the selection entropy over good workers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Reporter, get_task, make_byz
from repro.core.aggregators import Krum
from repro.core.mixing import get_mixer
from repro.data.partition import worker_datasets
from repro.data.pipeline import sample_worker_batches
from repro.models.mlp import init_mlp, nll_loss
from repro.training.byzantine import label_flip_targets, stack_flatten_workers

N, F = 20, 3


def main(steps: int = 150, reporter=None):
    rep = reporter or Reporter("krum_selection")
    X, Y, Xt, Yt = get_task()
    wx, wy = worker_datasets(X, Y, n_good=N - F, n_byz=F, noniid=True)
    wy = np.asarray(wy)
    wy[:F] = np.asarray(label_flip_targets(jnp.asarray(wy[:F])))
    wx, wy = jnp.asarray(wx), jnp.asarray(wy)
    params = init_mlp(jax.random.PRNGKey(1))
    grad_fn = jax.jit(jax.vmap(jax.grad(nll_loss), in_axes=(None, 0, 0)))
    krum = Krum(n_byzantine=F)

    for s in (0, 2, 3):
        mixer = get_mixer("none" if s == 0 else "bucketing", max(s, 1))
        byz_frac = []
        counts = np.zeros(N)
        for t in range(steps):
            key = jax.random.PRNGKey(t)
            bx, by = sample_worker_batches(key, wx, wy, 32)
            g = stack_flatten_workers(grad_fn(params, bx, by))
            m = mixer.matrix(jax.random.fold_in(key, 1), N)
            mixed = m @ g
            sel = int(jnp.argmin(krum.scores(mixed @ mixed.T)))
            src = np.where(np.asarray(m)[sel] > 0)[0]
            byz_frac.append(float(np.any(src < F)))
            counts[src] += 1.0 / len(src)
        good_counts = counts[F:]
        p = good_counts / max(good_counts.sum(), 1e-9)
        entropy = float(-(p[p > 0] * np.log(p[p > 0])).sum() / np.log(N - F))
        rep.add(f"s={s}/byz_selected_frac", float(np.mean(byz_frac)))
        rep.add(f"s={s}/good_selection_entropy", entropy)
    return rep


if __name__ == "__main__":
    main()
