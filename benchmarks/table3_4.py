"""Tables 3 & 4: Tables 1 & 2 re-run with bucketing s=2 — the paper's fix.

Paper: bucketing lifts Krum/CM/RFA by 10-25 points in the non-iid columns
(Table 3: Krum 97.8, CM 96.4, RFA 97.8 non-iid; Table 4: RFA 91.3,
CCLIP 91.2 under mimic).
"""

from __future__ import annotations

from benchmarks import table1, table2
from benchmarks.common import Reporter


def main(steps: int = 300):
    rep3 = Reporter("table3")
    table1.main(steps=steps, mixing="bucketing", s=2, reporter=rep3)
    rep4 = Reporter("table4")
    table2.main(steps=steps, mixing="bucketing", s=2, reporter=rep4)
    return rep3, rep4


if __name__ == "__main__":
    main()
