"""Figure 8 (App. A.2.4): bucketing vs resampling — near-identical accuracy,
with bucketing reducing the aggregator's input count (n -> n/s).

Also covers Figure 11 (App. A.2.6): fixed grouping (Chen et al. 2017) is
better than vanilla but weaker than per-round random bucketing.
"""

from __future__ import annotations

from benchmarks.common import Reporter, is_label_flip, make_byz, run_cell

N, F = 24, 3


def main(steps: int = 300, reporter=None):
    rep = reporter or Reporter("fig8")
    for attack in ("bf", "mimic", "ipm"):
        for mixing in ("none", "bucketing", "resampling", "fixed_grouping"):
            byz = make_byz("rfa", mixing, 2, attack, N, F)
            acc = run_cell(byz, n=N, f=F, noniid=True, steps=steps,
                           label_flip=is_label_flip(attack))
            rep.add(f"{attack}/{mixing}", acc)
    return rep


if __name__ == "__main__":
    main()
