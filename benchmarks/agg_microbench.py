"""Aggregator micro-benchmark: wall-time per aggregation call (stacked path
and Pallas-kernel path) vs worker count and gradient dimension.

This is the systems-side benchmark backing the paper's complexity table
(Krum O(n^2 d), CM/RFA O(n d)) and the bucketing claim that shrinking the
input set n -> n/s cuts aggregation cost.

Two engine sweeps back the packed flat-buffer engine
(repro/distributed/packing.py):

- ``sync/*``  : ``robust_gradient_sync`` packed vs per-leaf at FIXED total
  parameter count while the leaf count grows — per-leaf pays two reshards
  and several launches per leaf, packed pays one of each per sync.
- ``cclip/*`` : fused one-pass-per-iteration CCLIP vs the pre-fusion
  norms-pass + combine-pass (+ pseudo-row stack copy) schedule.
- ``egress/*``: HLO collective BYTES (not wall time) of the packed engine's
  replicated vs param-sharded egress on a forced 8-device host mesh —
  compiled in a subprocess so this process keeps the real single device.

``main()`` writes the machine-readable results to
``BENCH_agg_microbench.json`` at the repo root. ``--smoke`` instead runs a
seconds-scale regression gate on the selection-network CM cells against the
committed BENCH rows (used by the CI ``bench-smoke`` job).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.common import Reporter
from repro.core.aragg import RobustAggregator
from repro.distributed.robust_sync import robust_gradient_sync
from repro.kernels import ops
from repro.telemetry import EventLog

# engine sweep: ~131k params split into L equal leaves (a transformer has
# hundreds of leaves; a fused MLP has a handful). block_d=128 keeps the
# packed layout padding-free down to 128-param leaves.
SYNC_TOTAL_D = 131_072
SYNC_LEAF_COUNTS = (1, 64, 1024)
SYNC_W = 16
SYNC_BLOCK_D = 128

# Wall-times of the order-statistic cells BEFORE the selection-network
# engine (odd-even transposition sort in the kernel, variadic jnp.sort /
# jnp.median in the core path), kept for the before/after summary so the
# speedup survives BENCH refreshes.
_PRE_SELECTION_BASELINES = {
    "core/cm+none/W=25": 395200.2,
    "kernels/cm/W=25": 554676.4,
}

# --smoke regression gate: fail if today's machine is slower than the
# committed BENCH row by more than this factor. The smoke sweep runs at a
# smaller d than the committed rows, which adds headroom on top of this —
# the gate only trips on algorithmic regressions (e.g. reintroducing the
# O(W^2) transposition sort), not machine noise.
SMOKE_CELLS = ("core/cm+none/W=25", "kernels/cm/W=25")
SMOKE_FACTOR = 2.0
SMOKE_D = 16_384


def _time(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _leafy_tree(key, W, total_d, n_leaves):
    """Per-worker gradient pytree: ``total_d`` params in ``n_leaves`` leaves."""
    per, rem = divmod(total_d, n_leaves)
    sizes = [per + (1 if i < rem else 0) for i in range(n_leaves)]
    ks = jax.random.split(key, n_leaves)
    return {f"leaf{i:04d}": jax.random.normal(k, (W, s), jnp.float32)
            for i, (k, s) in enumerate(zip(ks, sizes))}


def sync_engine_sweep(rep, key):
    """Packed vs per-leaf robust_gradient_sync, leaf count varied at fixed
    total params (jnp contraction route in both engines: the comparison
    isolates the per-leaf scheduling overhead, not kernel dispatch).

    Single-CPU-device caveat: the reshard collectives are no-ops here, so
    the per-leaf engine is spared its dominant real-world cost (two
    collectives per leaf per step). What remains measurable on CPU is the
    per-leaf op overhead — decisive for the sort-based CM rule at high leaf
    counts, near-parity for the matmul-based Gram rules."""
    for agg, mixing in [("rfa", "bucketing"), ("cm", "bucketing")]:
        ra = RobustAggregator.from_spec(agg, mixing=mixing, s=2)
        for L in SYNC_LEAF_COUNTS:
            tree = _leafy_tree(jax.random.fold_in(key, L), SYNC_W,
                               SYNC_TOTAL_D, L)
            for engine in ("packed", "per_leaf"):
                call = jax.jit(
                    lambda t, k, _e=engine, _ra=ra: robust_gradient_sync(
                        t, _ra, key=k, engine=_e, use_kernels=False,
                        block_d=SYNC_BLOCK_D)[0]
                )
                us = _time(call, tree, key)
                rep.add(f"sync/{agg}/{engine}/L={L}", us)


_EGRESS_CHILD = r"""
import json, jax, jax.numpy as jnp
from repro.configs.base import ByzConfig
from repro.distributed.robust_sync import robust_gradient_sync
from repro.distributed.sharding import param_shardings
from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh(data=4, model=2)
W = 8
tree = {"wq": jnp.zeros((W, 512, 512), jnp.float32),
        "wff": jnp.zeros((W, 512, 2048), jnp.float32)}
ra = ByzConfig(aggregator="rfa", mixing="bucketing", s=2).make_aggregator(W)
shapes = jax.tree_util.tree_map(
    lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), tree)
out_sh = param_shardings(shapes, mesh, fsdp=True)

def sync(t, k, osh=None):
    return robust_gradient_sync(t, ra, key=k, mesh=mesh, engine="packed",
                                use_kernels=False, out_shardings=osh)[0]

k0 = jax.random.PRNGKey(0)
with mesh:
    rep = jax.jit(sync).lower(tree, k0).compile().as_text()
    par = jax.jit(lambda t, k: sync(t, k, out_sh)).lower(tree, k0).compile().as_text()
print(json.dumps({"replicated": sum(collective_bytes(rep).values()),
                  "param_sharded": sum(collective_bytes(par).values())}))
"""


def egress_bytes_sweep(rep):
    """Collective bytes of the two packed-engine egress modes (module
    docstring). Compiled on 8 forced host devices in a child process."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    root = Path(__file__).resolve().parents[1]
    env["PYTHONPATH"] = str(root / "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _EGRESS_CHILD], env=env,
                          capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        print(f"  egress sweep skipped: {proc.stderr[-300:]}", flush=True)
        return
    bytes_by_mode = json.loads(proc.stdout.strip().splitlines()[-1])
    for mode, b in bytes_by_mode.items():
        rep.add(f"egress/{mode}/coll_bytes", float(b))


def cclip_fusion_sweep(rep, key):
    """Fused (one HBM pass/iteration) vs unfused CCLIP kernel schedule."""
    xs = jax.random.normal(key, (25, 100_352), jnp.float32)
    rep.add("cclip/fused/W=25",
            _time(lambda x: ops.cclip_aggregate(x, 10.0), xs, iters=3))
    rep.add("cclip/unfused/W=25",
            _time(lambda x: ops.cclip_aggregate_unfused(x, 10.0), xs, iters=3))


def _write_json(rep):
    def val(cell):
        return next(r["value"] for r in rep.rows if r["cell"] == cell)

    summary = {}
    L = max(SYNC_LEAF_COUNTS)
    for agg in ("rfa", "cm"):
        try:
            summary[f"{agg}_packed_speedup_L{L}"] = (
                val(f"sync/{agg}/per_leaf/L={L}")
                / val(f"sync/{agg}/packed/L={L}")
            )
        except StopIteration:
            pass
    try:
        summary["cclip_fused_speedup"] = (
            val("cclip/unfused/W=25") / val("cclip/fused/W=25")
        )
    except StopIteration:
        pass
    try:
        summary["egress_bytes_ratio"] = (
            val("egress/replicated/coll_bytes")
            / max(val("egress/param_sharded/coll_bytes"), 1.0)
        )
    except StopIteration:
        pass
    for cell, before in _PRE_SELECTION_BASELINES.items():
        try:
            summary[f"selection_speedup[{cell}]"] = before / val(cell)
        except StopIteration:
            pass
    path = Path(__file__).resolve().parents[1] / "BENCH_agg_microbench.json"
    path.write_text(json.dumps(
        {"benchmark": rep.name, "units": "us_per_call", "rows": rep.rows,
         "summary": summary},
        indent=2,
    ) + "\n")
    print(f"  wrote {path}", flush=True)


def smoke_check() -> int:
    """CI regression gate: re-measure the order-statistic cells at a reduced
    d and compare against the committed BENCH rows (x SMOKE_FACTOR). Returns
    a process exit code. O(seconds), no JSON write."""
    path = Path(__file__).resolve().parents[1] / "BENCH_agg_microbench.json"
    committed = {r["cell"]: r["value"]
                 for r in json.loads(path.read_text())["rows"]}
    key = jax.random.PRNGKey(0)
    W = 25
    xs = jax.random.normal(key, (W, SMOKE_D), jnp.float32)
    ra = RobustAggregator.from_spec("cm", mixing="none", s=2)
    measured = {
        "core/cm+none/W=25": _time(jax.jit(lambda x, k: ra(x, key=k)),
                                   xs, key, iters=5),
        "kernels/cm/W=25": _time(ops.cm_aggregate, xs, iters=3),
    }
    failed = False
    for cell in SMOKE_CELLS:
        limit = committed[cell] * SMOKE_FACTOR
        us = measured[cell]
        status = "FAIL" if us > limit else "ok"
        failed |= us > limit
        print(f"  [{status}] {cell}: {us:.1f} us at d={SMOKE_D} "
              f"(limit {limit:.1f} us = committed {committed[cell]:.1f} "
              f"x {SMOKE_FACTOR})", flush=True)
    return 1 if failed else 0


def main(reporter=None):
    # standalone runs also stream every row as a `bench_row` structured
    # event — same JSONL schema as the probe script and the simulators
    # (repro/telemetry/events.py), so downstream tooling parses one format.
    log = None
    if reporter is None:
        root = Path(__file__).resolve().parents[1]
        log = EventLog(root / "BENCH_agg_microbench.jsonl",
                       run_id="agg_microbench")
        log.run_meta(benchmark="agg_microbench", units="us_per_call")
        reporter = Reporter("agg_microbench", event_log=log)
    rep = reporter
    key = jax.random.PRNGKey(0)
    for (W, d) in [(25, 100_352), (53, 100_352)]:
        xs = jax.random.normal(key, (W, d), jnp.float32)
        for agg, mixing in [("krum", "none"), ("cm", "none"), ("tm", "none"),
                            ("rfa", "none"), ("cclip", "none"),
                            ("rfa", "bucketing")]:
            kwargs = {"tau": 10.0} if agg == "cclip" else (
                {"n_byzantine": W // 10} if agg == "krum" else (
                    {"n_trim": W // 10} if agg == "tm" else {}))
            ra = RobustAggregator.from_spec(agg, mixing=mixing, s=2, **kwargs)
            call = jax.jit(lambda x, k, _ra=ra: _ra(x, key=k))
            us = _time(call, xs, key)
            rep.add(f"core/{agg}+{mixing}/W={W}", us)
        # kernel path (interpret mode on CPU — TPU-native on device)
        rep.add(f"kernels/cm/W={W}", _time(ops.cm_aggregate, xs, iters=3))
        rep.add(f"kernels/tm/W={W}",
                _time(lambda x: ops.tm_aggregate(x, W // 10), xs, iters=3))
        rep.add(f"kernels/gram/W={W}", _time(ops.gram, xs, iters=3))
    sync_engine_sweep(rep, jax.random.fold_in(key, 1))
    cclip_fusion_sweep(rep, jax.random.fold_in(key, 2))
    egress_bytes_sweep(rep)
    _write_json(rep)
    if log is not None:
        log.close()
    return rep


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI gate: compare the CM cells against the "
                         "committed BENCH rows instead of a full sweep")
    if ap.parse_args().smoke:
        sys.exit(smoke_check())
    main()
