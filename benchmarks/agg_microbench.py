"""Aggregator micro-benchmark: wall-time per aggregation call (stacked path
and Pallas-kernel path) vs worker count and gradient dimension.

This is the systems-side benchmark backing the paper's complexity table
(Krum O(n^2 d), CM/RFA O(n d)) and the bucketing claim that shrinking the
input set n -> n/s cuts aggregation cost.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Reporter
from repro.core.aragg import RobustAggregator
from repro.kernels import ops


def _time(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def main(reporter=None):
    rep = reporter or Reporter("agg_microbench")
    key = jax.random.PRNGKey(0)
    for (W, d) in [(25, 100_352), (53, 100_352)]:
        xs = jax.random.normal(key, (W, d), jnp.float32)
        for agg, mixing in [("krum", "none"), ("cm", "none"), ("rfa", "none"),
                            ("cclip", "none"), ("rfa", "bucketing")]:
            kwargs = {"tau": 10.0} if agg == "cclip" else (
                {"n_byzantine": W // 10} if agg == "krum" else {})
            ra = RobustAggregator.from_spec(agg, mixing=mixing, s=2, **kwargs)
            call = jax.jit(lambda x, k, _ra=ra: _ra(x, key=k))
            us = _time(call, xs, key)
            rep.add(f"core/{agg}+{mixing}/W={W}", us)
        # kernel path (interpret mode on CPU — TPU-native on device)
        rep.add(f"kernels/cm/W={W}", _time(ops.cm_aggregate, xs, iters=3))
        rep.add(f"kernels/gram/W={W}", _time(ops.gram, xs, iters=3))
    return rep


if __name__ == "__main__":
    main()
