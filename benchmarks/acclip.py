"""Beyond-paper: ACClip (adaptive clipping radius) vs fixed-tau CCLIP.

The paper's §6.4 leaves adaptive tau as an open problem: CCLIP "is not
agnostic since it requires clipping radius tau as an input which in turn
depends on rho^2". We certify agnosticity directly with the Definition-A
metric: for good workers with pairwise spread rho at scales spanning five
orders of magnitude (plus delta = 0.2 Byzantine outliers at 20x the good
scale), report the normalized aggregation error

    E ||AGG(x) - xbar_good||^2 / (delta * rho^2)

(Definition A demands this stays <= c for a scale-independent constant c.)
Fixed tau = 10 fails on both sides — at rho >> tau it over-clips the good
updates (cannot track xbar), at rho << tau it never binds and the
Byzantine bias passes through. ACClip's median-distance radius keeps the
normalized error flat.

Also reports the end-to-end training view (IPM, non-iid) at loss scales
kappa in {1, 100} for completeness.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Reporter
from repro.core.aggregators import get_aggregator

N, F, D = 25, 5, 64  # delta = 0.2


def norm_error(agg, rho: float, key, n_draws: int = 8) -> float:
    errs = []
    for i in range(n_draws):
        k = jax.random.fold_in(key, i)
        good = rho * jax.random.normal(k, (N - F, D))
        xbar = jnp.mean(good, axis=0)
        byz = jnp.full((F, D), 20.0 * rho)
        xs = jnp.concatenate([byz, good], axis=0)
        out = agg.aggregate(xs)
        errs.append(float(jnp.sum(jnp.square(out - xbar))))
    delta = F / N
    return float(np.mean(errs) / (delta * rho**2 * D))


def main(steps: int = 300, reporter=None):
    rep = reporter or Reporter("acclip")
    key = jax.random.PRNGKey(0)
    aggs = {
        "cclip_tau10": get_aggregator("cclip", tau=10.0, n_iters=5),
        "acclip": get_aggregator("acclip", n_iters=5),
        "mean": get_aggregator("mean"),
    }
    for rho in (0.01, 1.0, 100.0):
        for name, agg in aggs.items():
            rep.add(f"defA_err/rho={rho:g}/{name}", norm_error(agg, rho, key))
    return rep


if __name__ == "__main__":
    main()
