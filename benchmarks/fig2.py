"""Figure 2: aggregators x attacks grid on non-iid data (n=25, f=5), with and
without bucketing, with worker momentum 0.9 (the paper's bottom rows).

Expected: bucketing improves nearly every (aggregator, attack) cell; IPM and
ALIE (variance-exploiting) are the hardest without mixing + momentum.
"""

from __future__ import annotations

from benchmarks.common import Reporter, is_label_flip, make_byz, run_cell

AGGS = ["krum", "cm", "rfa", "cclip"]
ATTACKS = ["bf", "lf", "mimic", "ipm", "alie"]
N, F = 25, 5


def main(steps: int = 300, momentum: float = 0.9, reporter=None):
    rep = reporter or Reporter("fig2")
    for attack in ATTACKS:
        for agg in AGGS:
            for mixing in ("none", "bucketing"):
                byz = make_byz(agg, mixing, 2, attack, N, F, momentum=momentum)
                acc = run_cell(byz, n=N, f=F, noniid=True, steps=steps,
                               label_flip=is_label_flip(attack))
                rep.add(f"{attack}/{agg}/{mixing}", acc)
    return rep


if __name__ == "__main__":
    main()
