"""Shared experiment runner for the paper-reproduction benchmarks.

Mirrors the paper's App. A.1 setup at CPU-tractable scale: the SynthMNIST
task (seeded 10-class Gaussian mixture, DESIGN.md §7), 784-128-10 MLP,
n workers with f Byzantine, sort-by-label non-iid partitions, optional
long-tail subsampling, message-level attacks, mixing + robust aggregation,
worker momentum. Every benchmark module builds its table/figure from
``run_cell``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ByzConfig
from repro.data.partition import long_tail_subsample, worker_datasets
from repro.data.synthetic import make_train_test
from repro.models.mlp import accuracy, init_mlp, nll_loss
from repro.telemetry import EventLog
from repro.training.byzantine import ByzantineSim, label_flip_targets

# benchmark-scale defaults (paper: 600/4500 iters, n<=53; CPU budget: below)
DEFAULT_STEPS = 300
N_TRAIN, N_TEST = 4000, 1000


_task_cache: Dict[Tuple, Tuple] = {}


def get_task(longtail_alpha: float = 1.0, seed: int = 0):
    """(X, Y, Xt, Yt) for the SynthMNIST task, optionally long-tailed."""
    k = (longtail_alpha, seed)
    if k not in _task_cache:
        key = jax.random.PRNGKey(seed)
        X, Y, Xt, Yt = make_train_test(key, n_train=N_TRAIN, n_test=N_TEST)
        if longtail_alpha > 1:
            Xn, Yn = long_tail_subsample(X, Y, longtail_alpha, seed=seed)
            Xtn, Ytn = long_tail_subsample(Xt, Yt, longtail_alpha, seed=seed + 1)
            _task_cache[k] = (Xn, Yn, Xtn, Ytn)
        else:
            _task_cache[k] = (np.asarray(X), np.asarray(Y), np.asarray(Xt),
                              np.asarray(Yt))
    return _task_cache[k]


def run_cell(
    byz: ByzConfig,
    n: int = 25,
    f: int = 5,
    noniid: bool = True,
    longtail_alpha: float = 1.0,
    steps: int = DEFAULT_STEPS,
    lr: float = 0.1,
    batch_size: int = 32,
    seed: int = 0,
    label_flip: bool = False,
) -> float:
    """One (aggregator x attack x dataset) cell -> final top-1 test accuracy.

    ``label_flip`` applies the paper's data-level LF attack (T(y) = 9 - y on
    the Byzantine workers' local datasets) instead of a message attack.
    """
    X, Y, Xt, Yt = get_task(longtail_alpha, seed)
    wx, wy = worker_datasets(X, Y, n_good=n - f, n_byz=f, noniid=noniid,
                             seed=seed)
    if label_flip and f > 0:
        wy = np.asarray(wy)
        wy[:f] = np.asarray(label_flip_targets(jnp.asarray(wy[:f])))
    # EMA momentum rescales the update by (1-beta); compensate the lr so all
    # momentum settings see comparable effective step sizes (the paper uses
    # the PyTorch convention where this factor is folded into m).
    eff_lr = lr / max(1.0 - byz.worker_momentum, 1e-2) if \
        byz.momentum_convention == "ema" and byz.worker_momentum > 0 else lr
    sim = ByzantineSim(loss_fn=nll_loss, byz=byz, n_workers=n, n_byzantine=f,
                       lr=eff_lr, batch_size=batch_size)
    params = init_mlp(jax.random.PRNGKey(seed + 1))
    Xt_j, Yt_j = jnp.asarray(Xt), jnp.asarray(Yt)
    state, hist = sim.run(params, jnp.asarray(wx), jnp.asarray(wy), steps,
                          jax.random.PRNGKey(seed + 2),
                          eval_fn=lambda p: accuracy(p, Xt_j, Yt_j),
                          eval_every=steps)
    return float(hist["eval"][-1])


def attack_config(attack: str, n: int, f: int) -> Tuple[str, tuple, bool]:
    """Map a paper attack name -> (message attack, kwargs, label_flip flag)."""
    if attack == "lf":
        return "none", (), True
    if attack == "ipm":
        return "ipm", (("eps", 0.1),), False
    if attack == "alie":
        return "alie", (("n", n), ("f", f)), False
    if attack == "mimic":
        return "mimic", (("warmup_steps", 50),), False
    return attack, (), False


def make_byz(agg: str, mixing: str, s: int, attack: str, n: int, f: int,
             momentum: float = 0.0) -> ByzConfig:
    msg_attack, kwargs, _ = attack_config(attack, n, f)
    return ByzConfig(
        aggregator=agg, mixing=mixing, s=s, delta=f / n if f else 0.0,
        worker_momentum=momentum, attack=msg_attack, attack_kwargs=kwargs,
        n_byzantine=f,
    )


def is_label_flip(attack: str) -> bool:
    return attack == "lf"


def timeit_us(fn, *args, iters: int = 20, warmup: int = 3, **kwargs) -> Dict[str, float]:
    """Wall-time ``fn(*args, **kwargs)`` honestly: ``perf_counter`` clock and
    ``jax.block_until_ready`` on every timed result, so async dispatch can't
    make device work look instant. Returns mean/min microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kwargs))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        times.append((time.perf_counter() - t0) * 1e6)
    arr = np.asarray(times)
    return {"mean_us": float(arr.mean()), "min_us": float(arr.min()),
            "max_us": float(arr.max()), "iters": iters}


class Reporter:
    """Collects (benchmark, cell, value) rows and prints the run.py CSV.

    With an ``EventLog`` attached, every row is also emitted as a
    ``bench_row`` structured event — the same JSONL schema the probe
    scripts and simulators write (repro/telemetry/events.py)."""

    def __init__(self, name: str, event_log: Optional[EventLog] = None):
        self.name = name
        self.rows = []
        self.event_log = event_log
        self._t0 = time.perf_counter()

    def add(self, cell: str, value: float, **extra):
        self.rows.append({"benchmark": self.name, "cell": cell,
                          "value": value, **extra})
        if self.event_log is not None:
            self.event_log.bench_row(
                self.name, {"cell": cell, **extra}, {"value": value})
        print(f"  {self.name:14s} {cell:42s} {value:.4f}", flush=True)

    def done(self) -> float:
        return time.perf_counter() - self._t0
