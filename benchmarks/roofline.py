"""Roofline table (deliverable g): reads the dry-run JSON produced by

    PYTHONPATH=src python -m repro.launch.dryrun --all --json dryrun.json

and renders EXPERIMENTS.md §Roofline: the three terms (compute / memory /
collective, in seconds), the dominant term, MODEL_FLOPS = 6*N*D (dense) or
6*N_active*D (MoE) vs compiled HLO FLOPs, and a one-line lever per row.

Run as a module to print the markdown table:
    PYTHONPATH=src python -m benchmarks.roofline dryrun.json
"""

from __future__ import annotations

import json
import sys
from typing import Dict, List

from repro.configs import INPUT_SHAPES, get_config

# The dry-run executes ONE step; model flops for that step:
#   train: 6 N D   (fwd 2ND + bwd 4ND), D = tokens in the global batch
#   prefill: 2 N D
#   decode: 2 N D with D = batch (one token per sequence)


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: 1 token/sequence


def render(rows: List[Dict]) -> str:
    out = [
        "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
        "| bottleneck | HLO TFLOPs/chip | model/HLO flops |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "error" in r or "skipped" in r:
            out.append(
                f"| {r['arch']} | {r['shape']} | - | - | - | - | "
                f"{r.get('error', r.get('skipped'))} | - | - |")
            continue
        mf = model_flops(r["arch"], r["shape"])
        hlo_total = r["flops"] * r["n_chips"]  # cost_analysis is per-chip
        ratio = mf / hlo_total if hlo_total else float("nan")
        out.append(
            "| {arch} | {shape} | {mesh} | {c:.2f} | {m:.2f} | {k:.2f} | "
            "{b} | {f:.2f} | {r:.2f} |".format(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                c=r["compute_s"] * 1e3, m=r["memory_s"] * 1e3,
                k=r["collective_s"] * 1e3,
                b=r["bottleneck"].replace("_s", ""),
                f=r["flops"] / 1e12, r=ratio,
            ))
    return "\n".join(out)


def main(path: str = "dryrun.json"):
    with open(path) as f:
        rows = json.load(f)
    print(render(rows))


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "dryrun.json")
