"""Benchmark entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--steps N] [--only name ...]
                                            [--csv out.csv]

Prints ``benchmark,cell,value`` CSV rows (top-1 test accuracy per cell, or
us/call for the microbench) plus per-benchmark wall time. Paper-scale
settings are documented in each module; the default --steps 300 keeps the
full sweep CPU-tractable while preserving every directional claim.
"""

from __future__ import annotations

import argparse
import csv
import sys
import time

from benchmarks import (
    acclip,
    agg_microbench,
    fig2,
    fig3,
    fig8,
    krum_selection,
    overparam,
    table1,
    table2,
    table3_4,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--csv", type=str, default=None)
    args = ap.parse_args()

    jobs = {
        "table1": lambda: table1.main(steps=args.steps),
        "table2": lambda: table2.main(steps=args.steps),
        "table3_4": lambda: table3_4.main(steps=args.steps),
        "fig2": lambda: fig2.main(steps=args.steps),
        "fig3": lambda: fig3.main(steps=args.steps),
        "fig8": lambda: fig8.main(steps=args.steps),
        "overparam": lambda: overparam.main(steps=args.steps),
        "krum_selection": lambda: krum_selection.main(steps=args.steps // 2),
        "acclip": lambda: acclip.main(steps=args.steps),
        "agg_microbench": agg_microbench.main,
    }
    selected = args.only or list(jobs)
    unknown = set(selected) - set(jobs)
    if unknown:
        ap.error(f"unknown benchmarks {sorted(unknown)}; have {sorted(jobs)}")

    all_rows = []
    for name in selected:
        print(f"== {name} ==", flush=True)
        t0 = time.perf_counter()
        out = jobs[name]()
        reps = out if isinstance(out, tuple) else (out,)
        for rep in reps:
            all_rows.extend(rep.rows)
        print(f"-- {name} done in {time.perf_counter() - t0:.0f}s", flush=True)

    print("\nbenchmark,cell,value")
    for r in all_rows:
        print(f"{r['benchmark']},{r['cell']},{r['value']:.4f}")

    if args.csv:
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=["benchmark", "cell", "value"])
            w.writeheader()
            for r in all_rows:
                w.writerow({k: r[k] for k in ("benchmark", "cell", "value")})
        print(f"wrote {args.csv}", file=sys.stderr)


if __name__ == "__main__":
    main()
