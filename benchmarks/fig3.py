"""Figure 3: CCLIP under IPM on non-iid data — (a) s-sweep at f=5 on the
n=53 cluster; (b) f-sweep at s=2.

Expected: larger s converges better (s=2 already good); s=2 holds as f
approaches 25% of n.
"""

from __future__ import annotations

from benchmarks.common import Reporter, make_byz, run_cell

N = 53


def main(steps: int = 300, reporter=None):
    rep = reporter or Reporter("fig3")
    # (a) fixed f=5, sweep s (s=1 with mixing none == no resampling)
    for s, mixing in [(0, "none"), (2, "bucketing"), (5, "bucketing")]:
        byz = make_byz("cclip", mixing, max(s, 1), "ipm", N, 5, momentum=0.9)
        acc = run_cell(byz, n=N, f=5, noniid=True, steps=steps)
        rep.add(f"s_sweep/s={s}", acc)
    # (b) fixed s=2, sweep f
    for f in (3, 6, 12):
        byz = make_byz("cclip", "bucketing", 2, "ipm", N, f, momentum=0.9)
        acc = run_cell(byz, n=N, f=f, noniid=True, steps=steps)
        rep.add(f"f_sweep/f={f}", acc)
    return rep


if __name__ == "__main__":
    main()
