"""Figure 7 / Theorem IV: overparameterization improves Byzantine-robust
convergence. We scale the MLP hidden width and train under IPM with
RFA + bucketing; wider models should reach lower train loss / higher
accuracy despite the attackers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import N_TEST, Reporter, get_task, make_byz
from repro.data.partition import worker_datasets
from repro.models.mlp import accuracy, init_mlp, nll_loss
from repro.training.byzantine import ByzantineSim

N, F = 25, 5


def main(steps: int = 300, reporter=None):
    rep = reporter or Reporter("overparam")
    X, Y, Xt, Yt = get_task()
    wx, wy = worker_datasets(X, Y, n_good=N - F, n_byz=F, noniid=True)
    Xt_j, Yt_j = jnp.asarray(Xt), jnp.asarray(Yt)
    byz = make_byz("rfa", "bucketing", 2, "ipm", N, F, momentum=0.9)
    for width in (16, 128, 512):
        sim = ByzantineSim(loss_fn=nll_loss, byz=byz, n_workers=N,
                           n_byzantine=F, lr=1.0, batch_size=32)
        params = init_mlp(jax.random.PRNGKey(1), sizes=(784, width, 10))
        state, hist = sim.run(params, jnp.asarray(wx), jnp.asarray(wy), steps,
                              jax.random.PRNGKey(2),
                              eval_fn=lambda p: accuracy(p, Xt_j, Yt_j),
                              eval_every=steps)
        rep.add(f"width={width}", hist["eval"][-1])
    return rep


if __name__ == "__main__":
    main()
