"""Table 2: the mimic attack (delta=0.2, n=25, f=5) on balanced data.

Paper: Avg 92.6/92.6, Krum 90.4/39.0, CM 91.0/54.2, RFA 93.1/76.4,
CCLIP 93.2/85.5 (iid/non-iid). Expected: median-family rules collapse on
non-iid under mimic; Avg is unaffected (mimic sends legitimate vectors).
"""

from __future__ import annotations

from benchmarks.common import Reporter, make_byz, run_cell

AGGS = ["mean", "krum", "cm", "rfa", "cclip"]
N, F = 25, 5


def main(steps: int = 300, mixing: str = "none", s: int = 2, reporter=None):
    rep = reporter or Reporter("table2" if mixing == "none" else "table4")
    for agg in AGGS:
        for noniid in (False, True):
            byz = make_byz(agg, mixing, s, "mimic", N, F)
            acc = run_cell(byz, n=N, f=F, noniid=noniid, steps=steps)
            rep.add(f"{agg}/{'noniid' if noniid else 'iid'}", acc)
    return rep


if __name__ == "__main__":
    main()
