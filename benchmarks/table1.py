"""Table 1: aggregators FAIL on imbalanced non-iid data with NO Byzantine
workers (delta=0, long-tail alpha=500).

Paper (MNIST, 4500 iters): Avg 98.8/98.8, Krum 98.1/83.0, CM 97.8/80.4,
RFA 98.7/84.8, CCLIP 98.8/98.2 (iid/non-iid). Expected directional result at
benchmark scale: Krum/CM/RFA lose >= several points moving iid -> non-iid
while Avg and CCLIP hold.
"""

from __future__ import annotations

from benchmarks.common import Reporter, make_byz, run_cell

AGGS = ["mean", "krum", "cm", "rfa", "cclip"]
N, F = 20, 0
ALPHA = 500.0


def main(steps: int = 300, mixing: str = "none", s: int = 2, reporter=None):
    rep = reporter or Reporter("table1" if mixing == "none" else "table3")
    for agg in AGGS:
        for noniid in (False, True):
            byz = make_byz(agg, mixing, s, "none", N, F)
            acc = run_cell(byz, n=N, f=F, noniid=noniid, longtail_alpha=ALPHA,
                           steps=steps)
            rep.add(f"{agg}/{'noniid' if noniid else 'iid'}", acc)
    return rep


if __name__ == "__main__":
    main()
