"""Render EXPERIMENTS.md §Repro markdown tables from the benchmark CSV.

    python scripts/render_repro.py bench_results.csv
"""

import csv
import sys
from collections import defaultdict


def main(path):
    rows = list(csv.DictReader(open(path)))
    by_bench = defaultdict(dict)
    for r in rows:
        by_bench[r["benchmark"]][r["cell"]] = float(r["value"])

    def pct(v):
        return f"{100 * v:.1f}"

    # tables 1-4: agg x {iid, noniid}
    for t, title in [("table1", "Table 1 (delta=0, long-tail alpha=500)"),
                     ("table2", "Table 2 (mimic, n=25 f=5)"),
                     ("table3", "Table 3 = Table 1 + bucketing s=2"),
                     ("table4", "Table 4 = Table 2 + bucketing s=2")]:
        if t not in by_bench:
            continue
        cells = by_bench[t]
        print(f"\n**{title}** — top-1 test acc %\n")
        print("| aggregator | iid | non-iid |")
        print("|---|---|---|")
        for agg in ("mean", "krum", "cm", "rfa", "cclip"):
            print(f"| {agg} | {pct(cells[f'{agg}/iid'])} | "
                  f"{pct(cells[f'{agg}/noniid'])} |")

    if "fig2" in by_bench:
        cells = by_bench["fig2"]
        print("\n**Figure 2** (non-iid, n=25 f=5, momentum 0.9) — "
              "acc % without -> with bucketing\n")
        print("| attack | krum | cm | rfa | cclip |")
        print("|---|---|---|---|---|")
        for atk in ("bf", "lf", "mimic", "ipm", "alie"):
            row = f"| {atk} |"
            for agg in ("krum", "cm", "rfa", "cclip"):
                a = cells[f"{atk}/{agg}/none"]
                b = cells[f"{atk}/{agg}/bucketing"]
                row += f" {pct(a)} -> {pct(b)} |"
            print(row)

    for name in ("fig3", "fig8", "overparam", "krum_selection"):
        if name not in by_bench:
            continue
        print(f"\n**{name}**\n")
        print("| cell | value |")
        print("|---|---|")
        for cell, v in by_bench[name].items():
            print(f"| {cell} | {v:.4f} |")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "bench_results.csv")
