#!/usr/bin/env python
"""Thin wrapper over ``python -m repro.analysis`` for people (and CI) who
prefer a script path. Forwards every argument."""

import os
import sys

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src"))

from repro.analysis.cli import main  # noqa: E402

if __name__ == "__main__":
    raise SystemExit(main())
