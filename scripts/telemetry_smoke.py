"""CI telemetry smoke: 5 telemetry-on rounds on 8 forced host devices.

Runs a tiny cross-device simulation (ALIE cohort attack, RFA + bucketing)
with the in-graph telemetry engine enabled, writes every round's
device-resident metrics pytree as ``round`` events through
``repro.telemetry.EventLog``, then re-reads the file with
``validate_jsonl`` — the full producer -> JSONL -> schema loop the
observability docs promise.  Exits nonzero if any metric is missing,
unregistered, or non-finite where finiteness is required.

Usage:  PYTHONPATH=src python scripts/telemetry_smoke.py [out.jsonl]

The 8 host devices are forced inside ``main`` before jax's backend
initializes, never at import time (ast-import-env-mutation).
"""

import os
import sys

N_DEVICES = 8
N_ROUNDS = 5


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    out_path = argv[0] if argv else "telemetry_smoke.jsonl"

    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={N_DEVICES} " + flags)

    import jax
    import numpy as np

    from repro.configs.base import ByzConfig
    from repro.data.partition import worker_datasets
    from repro.data.synthetic import make_train_test
    from repro.models.mlp import init_mlp, nll_loss
    from repro.telemetry import EventLog, validate_jsonl
    from repro.training.cross_device import CrossDeviceSim

    assert jax.device_count() == N_DEVICES, (
        f"expected {N_DEVICES} forced host devices, got {jax.device_count()}")

    X, Y, _, _ = make_train_test(jax.random.PRNGKey(0), n_train=1200,
                                 n_test=100)
    wx, wy = worker_datasets(X, Y, n_good=18, n_byz=2, noniid=True)
    byz = ByzConfig(aggregator="rfa", mixing="bucketing", s=2, attack="alie",
                    attack_kwargs=(("n", 10), ("f", 2)), n_byzantine=0)
    sim = CrossDeviceSim(loss_fn=nll_loss, byz=byz, n_clients=20,
                         byz_frac=0.1, clients_per_round=10, lr=0.5,
                         batch_size=16, telemetry=True)

    params = init_mlp(jax.random.PRNGKey(1))
    if os.path.exists(out_path):
        os.remove(out_path)
    with EventLog(out_path, run_id="telemetry_smoke") as log:
        log.run_meta(script="telemetry_smoke", n_devices=jax.device_count(),
                     rounds=N_ROUNDS, aggregator=byz.aggregator,
                     mixing=byz.mixing, attack=byz.attack)
        _, hist = sim.run(params, np.asarray(wx), np.asarray(wy), N_ROUNDS,
                          jax.random.PRNGKey(2))
        tele = hist["telemetry"]
        assert tele, "telemetry-on run produced an empty metrics pytree"
        for t in range(N_ROUNDS):
            log.round(t, {name: arr[t] for name, arr in tele.items()})

    events = validate_jsonl(out_path)
    rounds = [e for e in events if e["kind"] == "round"]
    assert len(rounds) == N_ROUNDS, (len(rounds), N_ROUNDS)
    names = sorted(rounds[0]["metrics"])
    for must in ("agg_norm", "byz_in_cohort", "byz_mask", "rfa_residual",
                 "sync_egress_bytes", "worker_weights"):
        assert must in names, f"round events missing metric {must!r}"
    for e in rounds:
        agg_norm = e["metrics"]["agg_norm"]
        assert np.isfinite(agg_norm), f"non-finite agg_norm: {agg_norm}"
    print(f"telemetry smoke OK: {len(events)} events "
          f"({len(rounds)} rounds) -> {out_path}")
    print(f"round metrics: {', '.join(names)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
