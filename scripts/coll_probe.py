"""Attribute collective bytes per op for one (arch, shape) train compile,
then compare the packed engine's two egress modes (replicated reshard-out vs
param-sharded unpack) on the same production mesh.

Besides the human-readable rows, every result is emitted as a ``probe``
structured event through ``repro.telemetry.EventLog`` — the same JSONL
schema the benchmark harness and simulators write. Pass ``--jsonl PATH`` to
persist the events (default: in-memory only, text output unchanged).

All work lives in ``main()``: the 512 placeholder host devices are forced
via ``repro.launch.dryrun.activate()`` right before the first backend init,
never at import time (ast-import-env-mutation).
"""
import sys


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    from repro.launch.dryrun import activate

    activate()
    import re

    import jax
    import jax.numpy as jnp

    from repro.configs import INPUT_SHAPES, get_config
    from repro.configs.base import ByzConfig
    from repro.distributed.packing import packer_for
    from repro.distributed.robust_sync import robust_gradient_sync
    from repro.distributed.sharding import param_shardings
    from repro.distributed.steps import batch_shardings, input_specs, make_train_step
    from repro.launch.hlo_analysis import collective_bytes, iter_collectives
    from repro.launch.mesh import make_production_mesh
    from repro.telemetry import EventLog

    jsonl_path = None
    if "--jsonl" in argv:
        i = argv.index("--jsonl")
        jsonl_path = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    arch = argv[0] if len(argv) > 0 else "tinyllama-1.1b"
    agg = argv[1] if len(argv) > 1 else "rfa"
    log = EventLog(jsonl_path, run_id="coll_probe")
    log.run_meta(script="coll_probe", arch=arch, aggregator=agg)
    byz = ByzConfig(aggregator=agg, mixing="bucketing", s=2,
                    worker_momentum=0.9, delta=0.1)
    cfg = get_config(arch)
    shape = INPUT_SHAPES["train_4k"]
    mesh = make_production_mesh()
    specs = input_specs(cfg, shape)
    b_sh = batch_shardings(cfg, shape, mesh)
    with mesh:
        step_fn, sh = make_train_step(cfg, byz, mesh)
        jitted = jax.jit(
            step_fn,
            in_shardings=(sh["params"], sh["opt_state"], sh["worker_m"],
                          sh["replicated"], b_sh),
            out_shardings=(sh["params"], sh["opt_state"], sh["worker_m"],
                           sh["replicated"]))
        compiled = jitted.lower(sh["params_shape"], sh["opt_shape"],
                                sh["wm_shape"],
                                jax.ShapeDtypeStruct((2,), jnp.uint32),
                                specs).compile()
    hlo = compiled.as_text()
    hlo_lines = hlo.splitlines()
    rows = []
    for kind, nbytes, line_no in iter_collectives(hlo):
        mm = re.search(r'op_name="([^"]*)"', hlo_lines[line_no - 1])
        rows.append((nbytes, kind, (mm.group(1) if mm else "?")[:100]))
    rows.sort(reverse=True)
    tot = sum(r[0] for r in rows)
    print(f"total coll bytes (scan body once): {tot/1e9:.1f} GB, {len(rows)} ops")
    for b, op, name in rows[:15]:
        print(f"{b/1e9:8.2f}GB {op:18s} {name}")
    log.probe("train_collectives", {
        "arch": arch, "aggregator": agg, "total_bytes": tot,
        "n_ops": len(rows),
        "top_ops": [{"bytes": b, "kind": op, "op_name": name}
                    for b, op, name in rows[:15]],
    })

    # ---- egress mode comparison (replicated reshard_out vs param-sharded)
    # Standalone packed sync on a synthetic FSDP-shardable tree: the egress
    # is the only difference between the two compiles, so the
    # collective-bytes delta IS the egress cost. (The train step above
    # already uses the param-sharded mode via make_train_step.)
    W = mesh.shape["data"] * mesh.shape.get("pod", 1)
    k0 = jax.random.PRNGKey(0)
    tree = {
        "wq": jnp.zeros((W, 2048, 2048), jnp.float32),
        "wff": jnp.zeros((W, 2048, 8192), jnp.float32),
    }
    ra = byz.make_aggregator(W)
    shapes = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), tree)
    out_sh = param_shardings(shapes, mesh, fsdp=True)
    n_pad = packer_for(tree).n_pad

    def sync(t, k, osh=None):
        out, _ = robust_gradient_sync(t, ra, key=k, mesh=mesh, engine="packed",
                                      use_kernels=False, out_shardings=osh)
        return out

    with mesh:
        rep_hlo = jax.jit(sync).lower(tree, k0).compile().as_text()
        par_hlo = jax.jit(lambda t, k: sync(t, k, out_sh)).lower(
            tree, k0).compile().as_text()
    rep_b, par_b = collective_bytes(rep_hlo), collective_bytes(par_hlo)
    print(f"\negress comparison ({W} workers, n_pad={n_pad}):")
    print(f"  replicated   : {sum(rep_b.values())/1e9:.3f} GB  {rep_b}"
          f"  (f32[{n_pad}] materialized: {f'f32[{n_pad}]' in rep_hlo})")
    print(f"  param-sharded: {sum(par_b.values())/1e9:.3f} GB  {par_b}"
          f"  (f32[{n_pad}] materialized: {f'f32[{n_pad}]' in par_hlo})")
    log.probe("egress_comparison", {
        "n_workers": W, "n_pad": n_pad,
        "replicated": {"total_bytes": sum(rep_b.values()), "by_kind": rep_b,
                       "npad_row_materialized": f"f32[{n_pad}]" in rep_hlo},
        "param_sharded": {"total_bytes": sum(par_b.values()), "by_kind": par_b,
                          "npad_row_materialized": f"f32[{n_pad}]" in par_hlo},
    })
    log.close()


if __name__ == "__main__":
    main()
