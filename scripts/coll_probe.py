"""Attribute collective bytes per op for one (arch, shape) train compile,
then compare the packed engine's two egress modes (replicated reshard-out vs
param-sharded unpack) on the same production mesh."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
import re, sys, jax, jax.numpy as jnp
from repro.configs import INPUT_SHAPES, get_config
from repro.configs.base import ByzConfig
from repro.distributed.steps import batch_shardings, input_specs, make_train_step
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_analysis import _parse_shape_bytes, collective_bytes

arch = sys.argv[1] if len(sys.argv) > 1 else "tinyllama-1.1b"
agg = sys.argv[2] if len(sys.argv) > 2 else "rfa"
byz = ByzConfig(aggregator=agg, mixing="bucketing", s=2, worker_momentum=0.9, delta=0.1)
cfg = get_config(arch)
shape = INPUT_SHAPES["train_4k"]
mesh = make_production_mesh()
specs = input_specs(cfg, shape)
b_sh = batch_shardings(cfg, shape, mesh)
with mesh:
    step_fn, sh = make_train_step(cfg, byz, mesh)
    jitted = jax.jit(step_fn,
        in_shardings=(sh["params"], sh["opt_state"], sh["worker_m"], sh["replicated"], b_sh),
        out_shardings=(sh["params"], sh["opt_state"], sh["worker_m"], sh["replicated"]))
    compiled = jitted.lower(sh["params_shape"], sh["opt_shape"], sh["wm_shape"],
                            jax.ShapeDtypeStruct((2,), jnp.uint32), specs).compile()
hlo = compiled.as_text()
rows = []
for line in hlo.splitlines():
    m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*?\)|[^\s]+)\s+([a-z\-]+)\(", line.strip())
    if not m:
        continue
    shape_str, op = m.group(1), m.group(2)
    if op in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute", "all-gather-start", "all-reduce-start"):
        mm = re.search(r'op_name="([^"]*)"', line)
        rows.append((_parse_shape_bytes(shape_str), op, (mm.group(1) if mm else "?")[:100]))
rows.sort(reverse=True)
tot = sum(r[0] for r in rows)
print(f"total coll bytes (scan body once): {tot/1e9:.1f} GB, {len(rows)} ops")
for b, op, name in rows[:15]:
    print(f"{b/1e9:8.2f}GB {op:18s} {name}")

# ---- egress mode comparison (replicated reshard_out vs param-sharded unpack)
# Standalone packed sync on a synthetic FSDP-shardable tree: the egress is
# the only difference between the two compiles, so the collective-bytes
# delta IS the egress cost. (The train step above already uses the
# param-sharded mode via make_train_step.)
from repro.distributed.robust_sync import robust_gradient_sync
from repro.distributed.sharding import param_shardings
from repro.distributed.packing import packer_for

W = mesh.shape["data"] * mesh.shape.get("pod", 1)
k0 = jax.random.PRNGKey(0)
tree = {
    "wq": jnp.zeros((W, 2048, 2048), jnp.float32),
    "wff": jnp.zeros((W, 2048, 8192), jnp.float32),
}
ra = byz.make_aggregator(W)
shapes = jax.tree_util.tree_map(
    lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), tree)
out_sh = param_shardings(shapes, mesh, fsdp=True)
n_pad = packer_for(tree).n_pad

def sync(t, k, osh=None):
    out, _ = robust_gradient_sync(t, ra, key=k, mesh=mesh, engine="packed",
                                  use_kernels=False, out_shardings=osh)
    return out

with mesh:
    rep_hlo = jax.jit(sync).lower(tree, k0).compile().as_text()
    par_hlo = jax.jit(lambda t, k: sync(t, k, out_sh)).lower(tree, k0).compile().as_text()
rep_b, par_b = collective_bytes(rep_hlo), collective_bytes(par_hlo)
print(f"\negress comparison ({W} workers, n_pad={n_pad}):")
print(f"  replicated   : {sum(rep_b.values())/1e9:.3f} GB  {rep_b}"
      f"  (f32[{n_pad}] materialized: {f'f32[{n_pad}]' in rep_hlo})")
print(f"  param-sharded: {sum(par_b.values())/1e9:.3f} GB  {par_b}"
      f"  (f32[{n_pad}] materialized: {f'f32[{n_pad}]' in par_hlo})")
