"""Serve a model with batched decode requests through the serving runtime.

Builds the decode cache, prefills it token-by-token with the prompt (the
same ``decode_step`` the dry-run lowers for the decode_32k / long_500k
shapes), then greedy-decodes a continuation for a whole batch of requests.

    PYTHONPATH=src python examples/serve_decode.py --arch mamba2-130m --new-tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import transformer as tfm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    B = args.batch
    total = args.prompt_len + args.new_tokens

    tok_shape = (B, cfg.n_codebooks) if cfg.n_codebooks else (B,)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 tok_shape + (args.prompt_len,), 0,
                                 cfg.vocab_size)

    decode = jax.jit(lambda p, c, t, pos: tfm.decode_step(p, cfg, c, t, pos))
    cache = tfm.init_cache(cfg, B, total)

    # prefill (token-by-token through the decode path)
    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = decode(params, cache, prompts[..., t],
                               jnp.asarray(t, jnp.int32))
    print(f"prefill {args.prompt_len} tokens x {B} requests: "
          f"{time.time() - t0:.2f}s")

    # greedy decode
    out = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t0 = time.time()
    for t in range(args.prompt_len, total):
        out.append(tok)
        logits, cache = decode(params, cache, tok, jnp.asarray(t, jnp.int32))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    dt = time.time() - t0
    gen = jnp.stack(out, axis=-1)
    print(f"decoded {args.new_tokens} tokens x {B} requests in {dt:.2f}s "
          f"({B * args.new_tokens / dt:.1f} tok/s)")
    print("sample:", gen.reshape(B, -1)[0][:16].tolist())


if __name__ == "__main__":
    main()
