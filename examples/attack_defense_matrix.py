"""Sweep the attack x defense matrix and print who wins.

A compact version of the paper's Figure 2 grid through the public API —
useful as a template for evaluating a new aggregator or a new attack against
the existing zoo.

    PYTHONPATH=src python examples/attack_defense_matrix.py --steps 150
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import ByzConfig
from repro.data.partition import worker_datasets
from repro.data.synthetic import make_train_test
from repro.models.mlp import accuracy, init_mlp, nll_loss
from repro.training.byzantine import ByzantineSim

N, F = 15, 3


def run(attack, agg, mixing, task, steps):
    X, Y, Xt, Yt = task
    wx, wy = worker_datasets(X, Y, n_good=N - F, n_byz=F, noniid=True)
    kwargs = (("n", N), ("f", F)) if attack == "alie" else ()
    byz = ByzConfig(aggregator=agg, mixing=mixing, s=2, worker_momentum=0.9,
                    attack=attack, attack_kwargs=kwargs, n_byzantine=F,
                    delta=F / N)
    sim = ByzantineSim(loss_fn=nll_loss, byz=byz, n_workers=N, n_byzantine=F,
                       lr=1.0, batch_size=32)
    params = init_mlp(jax.random.PRNGKey(1))
    Xt, Yt = jnp.asarray(Xt), jnp.asarray(Yt)
    _, hist = sim.run(params, jnp.asarray(wx), jnp.asarray(wy), steps,
                      jax.random.PRNGKey(2),
                      eval_fn=lambda p: accuracy(p, Xt, Yt), eval_every=steps)
    return hist["eval"][-1]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()

    task = make_train_test(jax.random.PRNGKey(0), n_train=3000)
    attacks = ["none", "bitflip", "mimic", "ipm", "alie"]
    defenses = [("mean", "none"), ("rfa", "none"), ("rfa", "bucketing"),
                ("cclip", "bucketing")]

    header = "attack".ljust(10) + "".join(
        f"{a}+{m}".ljust(18) for a, m in defenses)
    print(header)
    for attack in attacks:
        row = attack.ljust(10)
        for agg, mixing in defenses:
            acc = run(attack, agg, mixing, task, args.steps)
            row += f"{acc:.3f}".ljust(18)
        print(row, flush=True)


if __name__ == "__main__":
    main()
