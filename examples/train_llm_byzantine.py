"""End-to-end driver: Byzantine-robust training of a ~100M-param LLM.

Uses the framework's full distributed stack — the generic pattern-scanned
transformer (here the mamba2-130m assigned architecture at its real size,
or any --arch), the distributed train step with robust gradient sync
replacing the mean all-reduce, worker momentum, checkpointing, and the
synthetic heterogeneous token pipeline (per-worker bigram "dialects").

Runs a few hundred steps on whatever devices exist (CPU: pass --preset cpu
for a reduced model; the same script drives the TPU mesh unchanged).

    PYTHONPATH=src python examples/train_llm_byzantine.py --steps 200 --preset cpu
    PYTHONPATH=src python examples/train_llm_byzantine.py --arch mamba2-130m  # full 130M
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.configs.base import ByzConfig
from repro.data.synthetic import make_token_stream
from repro.distributed.steps import make_train_step
from repro.launch.mesh import make_host_mesh, n_workers
from repro.models import transformer as tfm
from repro.optim import make_optimizer
from repro.training.checkpoint import save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--preset", choices=["cpu", "full"], default="cpu")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8, help="global batch")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--agg", default="rfa")
    ap.add_argument("--mixing", default="bucketing")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.preset == "cpu" else get_config(args.arch)
    if args.preset == "full":
        cfg = dataclasses.replace(cfg, dtype="float32")
    mesh = make_host_mesh(1, 1)  # swap for make_production_mesh() on TPU
    W = n_workers(mesh)
    byz = ByzConfig(aggregator=args.agg, mixing=args.mixing, s=2,
                    worker_momentum=0.9, delta=0.1)

    print(f"arch={cfg.name} params={cfg.param_count():,} workers={W} "
          f"agg={args.agg}+{args.mixing}")

    with mesh:
        step_fn, sh = make_train_step(cfg, byz, mesh, lr=args.lr,
                                      optimizer="adamw")
        step_fn = jax.jit(step_fn)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        opt_init, _ = make_optimizer("adamw", lr=args.lr)
        opt_state = opt_init(params)
        worker_m = jax.tree_util.tree_map(
            lambda x: jnp.zeros((W,) + x.shape, jnp.float32), params
        ) if sh["worker_m"] else {}

        # heterogeneous per-worker token streams (non-iid "dialects")
        streams = make_token_stream(jax.random.PRNGKey(1), n_workers=W,
                                    seq_len=args.seq_len,
                                    n_seqs_per_worker=64,
                                    vocab=cfg.vocab_size)

        t0 = time.time()
        for t in range(args.steps):
            k = jax.random.fold_in(jax.random.PRNGKey(2), t)
            idx = jax.random.randint(k, (W, args.batch // W), 0,
                                     streams.shape[1])
            seqs = jnp.take_along_axis(streams, idx[..., None], axis=1)
            seqs = seqs.reshape(args.batch, -1)
            batch = {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}
            params, opt_state, worker_m, metrics = step_fn(
                params, opt_state, worker_m, k, batch)
            if t % 20 == 0 or t == args.steps - 1:
                print(f"step {t:5d}  loss {float(metrics['loss']):.4f}  "
                      f"({time.time() - t0:.0f}s)")

        path = save_checkpoint(args.ckpt_dir, args.steps,
                               {"params": params, "opt": opt_state})
        print(f"checkpoint -> {path}")


if __name__ == "__main__":
    main()
