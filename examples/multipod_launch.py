"""Launch-script example for the production mesh (dry-run on CPU).

Shows exactly what a real multi-pod TPU launch does: build the
(pod, data, model) mesh, construct shardings for params / optimizer state /
worker momentum, lower + compile the robust train step for an assigned
architecture, and report the memory/roofline numbers — without allocating
any arrays (ShapeDtypeStruct only), so it runs anywhere.

    PYTHONPATH=src python examples/multipod_launch.py --arch olmoe-1b-7b --shape train_4k
    PYTHONPATH=src python examples/multipod_launch.py --arch kimi-k2-1t-a32b --multi-pod
"""

# The placeholder-device env var must be set before jax initializes.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--agg", default="rfa")
    ap.add_argument("--mixing", default="bucketing")
    args = ap.parse_args()

    from repro.configs.base import ByzConfig
    from repro.launch.dryrun import dryrun_one

    byz = ByzConfig(aggregator=args.agg, mixing=args.mixing, s=2,
                    worker_momentum=0.9, delta=0.1)
    result = dryrun_one(args.arch, args.shape, multi_pod=args.multi_pod,
                        byz=byz, verbose=True)
    if "error" in result:
        raise SystemExit(f"dry-run failed: {result['error']}")
    print("\nThis exact jit/lower/compile path runs unchanged on the real "
          "TPU mesh; only the device list changes.")


if __name__ == "__main__":
    main()
