"""Quickstart: Byzantine-robust training in ~40 lines.

Trains the paper's MLP on the heterogeneous SynthMNIST task with 25 workers,
5 of them running the mimic attack, defended by RFA + bucketing (s=2) +
worker momentum — the paper's recommended recipe (Algorithm 1 + 2).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs.base import ByzConfig
from repro.data.partition import worker_datasets
from repro.data.synthetic import make_train_test
from repro.models.mlp import accuracy, init_mlp, nll_loss
from repro.training.byzantine import ByzantineSim


def main():
    n_workers, n_byzantine = 25, 5

    # 1. a heterogeneous federated dataset: sort-by-label non-iid split
    X, Y, Xt, Yt = make_train_test(jax.random.PRNGKey(0), n_train=4000)
    wx, wy = worker_datasets(X, Y, n_good=n_workers - n_byzantine,
                             n_byz=n_byzantine, noniid=True)

    # 2. the paper's technique as a config: bucketing + robust agg + momentum
    byz = ByzConfig(
        aggregator="rfa",        # geometric median (Weiszfeld)
        mixing="bucketing",      # Algorithm 1, camera-ready variant
        s=2,                     # paper's recommended mild mixing
        worker_momentum=0.9,     # Algorithm 2
        attack="mimic",          # what the Byzantine workers do
        n_byzantine=n_byzantine,
        delta=n_byzantine / n_workers,
    )

    # 3. train
    sim = ByzantineSim(loss_fn=nll_loss, byz=byz, n_workers=n_workers,
                       n_byzantine=n_byzantine, lr=1.0, batch_size=32)
    params = init_mlp(jax.random.PRNGKey(1))
    Xt, Yt = jnp.asarray(Xt), jnp.asarray(Yt)
    state, hist = sim.run(params, jnp.asarray(wx), jnp.asarray(wy),
                          n_steps=300, key=jax.random.PRNGKey(2),
                          eval_fn=lambda p: accuracy(p, Xt, Yt),
                          eval_every=50)

    for step, acc in zip(hist["step"], hist["eval"]):
        print(f"step {step:4d}  test accuracy {acc:.3f}")
    assert hist["eval"][-1] > 0.7, "defense failed!"
    print("defended against the mimic attack.")


if __name__ == "__main__":
    main()
